//! `repro bench` — the measured performance surface of the stack.
//!
//! Runs four workloads and writes a schema-versioned `BENCH_v1.json`
//! trajectory so every optimization lands with numbers attached and CI can
//! gate regressions (ucTrace's discipline: a profiler publishes its own
//! overhead):
//!
//! 1. **Smoke-matrix cell throughput** — every ≤16-rank cell of the
//!    Table III matrix, run end-to-end (`run_cell_full`, smoke fidelity),
//!    several repetitions; reported as the median and p90 of the per-cell
//!    cells/second distribution. This is the number the tentpole's ≥2×
//!    target is judged by, and what the CI gate compares.
//! 2. **Hook dispatch** — the `comm-stats` pipeline fed a realistic
//!    event mix (same mix as the `hookpath` bench); ns per event.
//! 3. **Trace capture** — the same mix with the `trace` channel on;
//!    events/second through the ring.
//! 4. **Allocations per message** — a 2-rank eager ping-pong measured
//!    under the counting allocator (`util::alloc`, installed by the
//!    `repro` binary only); heap allocations divided by messages sent.
//!
//! The JSON file is append-only: each run adds one labelled entry, so the
//! committed file is a baseline→optimized trajectory, not a single point.
//! `--check` compares the new throughput distribution against the last
//! committed entry with Welch's t-test over the stored moments (the same
//! significance machinery as `repro diff`, see [`crate::store::diff`]) and
//! fails only on a statistically significant drop past the tolerance;
//! entries committed before the moments existed fall back to the old
//! median heuristic. See `docs/PERFORMANCE.md`.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::benchpark::runner::{run_cell_full, table3_matrix, RunOptions};
use crate::caliper::channel::ChannelConfig;
use crate::caliper::comm_profiler::CommProfiler;
use crate::mpisim::{CollKind, MachineModel, MpiEvent, MpiHook, World, WorldConfig};
use crate::store::diff::{welch_from_moments, DiffVerdict};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::stats::{percentile, OnlineStats};

/// Schema tag stamped into the JSON file; bump on incompatible change.
pub const BENCH_SCHEMA: &str = "BENCH_v1";

/// Throughput-drop fraction the regression gate tolerates (`--check`):
/// new median cell throughput must stay ≥ (1 - 0.15) × last committed.
pub const REGRESSION_TOLERANCE: f64 = 0.15;

/// Rank ceiling for the smoke-matrix section; keeps a bench run fast
/// enough for per-PR CI while still covering every app × system pair.
const SMOKE_MAX_RANKS: usize = 16;

/// One measured bench entry (one run of the suite).
#[derive(Debug, Clone)]
pub struct BenchEntry {
    pub label: String,
    /// Median of the per-cell throughput distribution (cells/second).
    pub smoke_cells_per_s_median: f64,
    /// 90th percentile of the same distribution (the fast tail).
    pub smoke_cells_per_s_p90: f64,
    /// Cells in the smoke matrix × repetitions behind the distribution.
    pub smoke_cells: usize,
    pub smoke_reps: usize,
    /// Events/second through the trace-enabled hook pipeline.
    pub events_per_s: f64,
    /// Nanoseconds per hook dispatch on the default `comm-stats` pipeline.
    pub ns_per_hook_dispatch: f64,
    /// Heap allocations per message in a 2-rank eager ping-pong
    /// (0.0 when the counting allocator is not installed, e.g. in tests).
    pub allocs_per_message: f64,
    /// Ranks simulated per wall-clock second on one
    /// [`EVENT_BENCH_RANKS`]-rank AMG2023/Tioga cell under the
    /// discrete-event engine — the scale metric behind `--extend-ranks`
    /// campaigns. 0.0 in entries recorded before the event engine existed.
    pub event_ranks_per_s: f64,
    /// Samples behind the throughput distribution (cells × reps). 0 in
    /// entries committed before the Welch gate landed — those fall back
    /// to the median heuristic in [`gate_verdict`].
    pub smoke_samples: usize,
    /// Mean of the per-cell throughput distribution (cells/second).
    pub smoke_cells_per_s_mean: f64,
    /// Sum of squared deviations (M2) of the same distribution — with
    /// `smoke_samples` and the mean, exactly the moments Welch's t-test
    /// consumes.
    pub smoke_cells_per_s_m2: f64,
    /// Gate verdict vs. the committed baseline at record time
    /// ("no-change" | "improved" | "regressed"; empty when there was no
    /// baseline to compare against).
    pub gate_verdict: String,
}

impl BenchEntry {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("label", self.label.as_str());
        j.set("smoke_cells_per_s_median", self.smoke_cells_per_s_median);
        j.set("smoke_cells_per_s_p90", self.smoke_cells_per_s_p90);
        j.set("smoke_cells", self.smoke_cells);
        j.set("smoke_reps", self.smoke_reps);
        j.set("events_per_s", self.events_per_s);
        j.set("ns_per_hook_dispatch", self.ns_per_hook_dispatch);
        j.set("allocs_per_message", self.allocs_per_message);
        j.set("event_ranks_per_s", self.event_ranks_per_s);
        j.set("smoke_samples", self.smoke_samples);
        j.set("smoke_cells_per_s_mean", self.smoke_cells_per_s_mean);
        j.set("smoke_cells_per_s_m2", self.smoke_cells_per_s_m2);
        j.set("gate_verdict", self.gate_verdict.as_str());
        j
    }

    pub fn from_json(j: &Json) -> Option<BenchEntry> {
        Some(BenchEntry {
            label: j.get("label")?.as_str()?.to_string(),
            smoke_cells_per_s_median: j.get("smoke_cells_per_s_median")?.as_f64()?,
            smoke_cells_per_s_p90: j.get("smoke_cells_per_s_p90")?.as_f64()?,
            smoke_cells: j.get("smoke_cells")?.as_u64()? as usize,
            smoke_reps: j.get("smoke_reps")?.as_u64()? as usize,
            events_per_s: j.get("events_per_s")?.as_f64()?,
            ns_per_hook_dispatch: j.get("ns_per_hook_dispatch")?.as_f64()?,
            allocs_per_message: j.get("allocs_per_message")?.as_f64()?,
            // Absent from entries committed before the event engine landed.
            event_ranks_per_s: j
                .get("event_ranks_per_s")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            // Moment fields are absent from entries committed before the
            // Welch gate; zeros route gate_verdict to the median fallback,
            // so old BENCH_v1.json files keep parsing (no schema break).
            smoke_samples: j
                .get("smoke_samples")
                .and_then(|v| v.as_u64())
                .unwrap_or(0) as usize,
            smoke_cells_per_s_mean: j
                .get("smoke_cells_per_s_mean")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            smoke_cells_per_s_m2: j
                .get("smoke_cells_per_s_m2")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            gate_verdict: j
                .get("gate_verdict")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
        })
    }
}

/// Parse the entries of a `BENCH_v1.json` document.
pub fn parse_bench_file(text: &str) -> Result<Vec<BenchEntry>> {
    let j = Json::parse(text).map_err(|e| anyhow::anyhow!("bench json: {}", e))?;
    let schema = j.get("schema").and_then(|s| s.as_str()).unwrap_or("");
    if schema != BENCH_SCHEMA {
        bail!("bench file schema '{}' != '{}'", schema, BENCH_SCHEMA);
    }
    let arr = j
        .get("entries")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| anyhow::anyhow!("bench file has no entries array"))?;
    let mut out = Vec::new();
    for (i, e) in arr.iter().enumerate() {
        out.push(
            BenchEntry::from_json(e)
                .ok_or_else(|| anyhow::anyhow!("bench entry {} is malformed", i))?,
        );
    }
    Ok(out)
}

/// Serialize entries as a `BENCH_v1.json` document.
pub fn render_bench_file(entries: &[BenchEntry]) -> String {
    let mut j = Json::obj();
    j.set("schema", BENCH_SCHEMA);
    j.set(
        "entries",
        Json::Arr(entries.iter().map(|e| e.to_json()).collect()),
    );
    let mut s = j.to_string_pretty();
    s.push('\n');
    s
}

/// The ≤`SMOKE_MAX_RANKS` slice of the Table III matrix the throughput
/// section runs. Apps whose smallest Table III cell already exceeds the
/// cap (Laghos starts at 112 ranks) contribute one representative cell
/// clamped to the cap, so the bench exercises every app's communication
/// pattern.
pub fn smoke_cells() -> Vec<crate::benchpark::ExperimentSpec> {
    let matrix = table3_matrix();
    let mut out: Vec<crate::benchpark::ExperimentSpec> = matrix
        .iter()
        .filter(|s| s.nranks <= SMOKE_MAX_RANKS)
        .copied()
        .collect();
    for spec in &matrix {
        if !out.iter().any(|s| s.app == spec.app) {
            let mut small = *spec;
            small.nranks = SMOKE_MAX_RANKS;
            out.push(small);
        }
    }
    out
}

/// Same realistic event mix as the `hookpath`/`tracepath` benches:
/// halo-style sends/recvs over a few peers plus occasional collectives.
fn event_mix(n: usize) -> Vec<MpiEvent> {
    let mut evs = Vec::with_capacity(n);
    for i in 0..n {
        let peer = i % 6;
        let bytes = 64 << (i % 7);
        let t = i as f64 * 1e-6;
        evs.push(match i % 8 {
            0..=3 => MpiEvent::Send {
                dst: peer,
                tag: (i % 16) as i32,
                bytes,
                t_start: t,
                t_end: t + 1e-7,
            },
            4..=6 => MpiEvent::Recv {
                src: peer,
                tag: (i % 16) as i32,
                bytes,
                t_start: t,
                t_end: t + 2e-7,
            },
            _ => MpiEvent::Coll {
                kind: CollKind::Allreduce,
                bytes: 8,
                comm_size: 8,
                t_start: t,
                t_end: t + 5e-7,
            },
        });
    }
    evs
}

/// Best-of-`reps` seconds per event for a channel spec.
fn per_event_cost(spec: &str, events: &[MpiEvent], reps: usize) -> f64 {
    let cfg = ChannelConfig::parse(spec).expect("valid spec");
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let mut p = CommProfiler::with_channels(0, cfg);
        p.begin("main", false, 0.0);
        p.begin("halo", true, 0.0);
        let t0 = Instant::now();
        for ev in events {
            p.on_event(0, ev);
        }
        let dt = t0.elapsed().as_secs_f64();
        p.end("halo", 1.0);
        p.end("main", 1.0);
        let prof = p.finish(1.0);
        assert!(prof.regions["main/halo"].visits > 0, "pipeline recorded");
        best = best.min(dt / events.len() as f64);
    }
    best
}

/// Per-cell wall-clock throughput over `reps` repetitions of the smoke
/// matrix. Bypasses the campaign executor on purpose: its content-keyed
/// dedup cache would serve repeat cells from memory and measure nothing.
fn smoke_throughput(run: &RunOptions, reps: usize) -> Result<(f64, f64, usize, OnlineStats)> {
    let cells = smoke_cells();
    if cells.is_empty() {
        bail!("smoke matrix is empty");
    }
    // Warmup: one cheapest cell, so thread spawn + allocator are hot.
    let _ = run_cell_full(&cells[0], run)?;
    let mut samples = Vec::with_capacity(cells.len() * reps);
    let mut moments = OnlineStats::new();
    for _ in 0..reps {
        for spec in &cells {
            let t0 = Instant::now();
            run_cell_full(spec, run)
                .with_context(|| format!("bench cell {}", spec.id()))?;
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            samples.push(1.0 / dt);
            moments.push(1.0 / dt);
        }
    }
    Ok((
        percentile(&samples, 50.0),
        // p90 cell: the fast tail of the distribution (10th percentile of
        // duration = 90th of throughput).
        percentile(&samples, 90.0),
        cells.len(),
        moments,
    ))
}

/// Allocations per message: 2-rank eager ping-pong under the counting
/// allocator. Returns 0.0 when the allocator is not installed (library
/// tests), because the counter never moves.
fn allocs_per_message(rounds: usize) -> f64 {
    let run = |rounds: usize| {
        let cfg = WorldConfig::new(2, MachineModel::test_machine());
        World::run(cfg, move |rank| {
            let world = rank.world();
            let peer = 1 - rank.rank;
            let buf = [0.0f64; 64]; // 512 B — comfortably eager
            for tag in 0..rounds as i32 {
                if rank.rank == 0 {
                    rank.send(&buf[..], peer, tag, &world).unwrap();
                    let _ = rank.recv::<f64>(Some(peer), tag, &world).unwrap();
                } else {
                    let _ = rank.recv::<f64>(Some(peer), tag, &world).unwrap();
                    rank.send(&buf[..], peer, tag, &world).unwrap();
                }
            }
        });
    };
    run(rounds.min(64)); // warmup
    let before = crate::util::alloc::allocation_count();
    run(rounds);
    let after = crate::util::alloc::allocation_count();
    let messages = (2 * rounds) as f64;
    (after - before) as f64 / messages
}

/// Rank count of the event-engine scale cell. Far past the smoke slice's
/// 16-rank cap — that is the point: thread-per-rank spends its time in
/// spawn/context-switch overhead there, the event engine does not.
pub const EVENT_BENCH_RANKS: usize = 256;

/// Event-engine scale metric: ranks simulated per wall-clock second on a
/// single [`EVENT_BENCH_RANKS`]-rank AMG2023/Tioga cell run under the
/// discrete-event scheduler (one worker — the deterministic default).
/// One cold run, spawn cost included: that is what an `--extend-ranks`
/// campaign actually pays per cell.
fn event_ranks_per_s(run: &RunOptions) -> Result<f64> {
    use crate::benchpark::experiment::Scaling;
    let spec = crate::benchpark::ExperimentSpec {
        app: crate::benchpark::AppKind::Amg2023,
        system: crate::benchpark::SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks: EVENT_BENCH_RANKS,
    };
    let mut opts = *run;
    opts.engine = crate::mpisim::Engine::event();
    let t0 = Instant::now();
    run_cell_full(&spec, &opts).context("event-engine bench cell")?;
    Ok(EVENT_BENCH_RANKS as f64 / t0.elapsed().as_secs_f64().max(1e-9))
}

/// Run the full suite and return one entry. `full` switches the smoke
/// matrix to non-shrunk fidelity (the nightly configuration).
pub fn run_suite(label: &str, full: bool, reps: usize) -> Result<BenchEntry> {
    let run = if full {
        RunOptions::default()
    } else {
        RunOptions::smoke()
    };
    eprintln!(
        "bench: smoke matrix ({} fidelity), {} reps...",
        if full { "full" } else { "smoke" },
        reps
    );
    let (median, p90, n_cells, moments) = smoke_throughput(&run, reps)?;
    eprintln!("bench: hook dispatch + trace capture...");
    let events = event_mix(300_000);
    let _ = per_event_cost("comm-stats", &events[..events.len() / 4], 1); // warmup
    let hook_cost = per_event_cost("comm-stats", &events, 5);
    let trace_cost = per_event_cost("comm-stats,trace", &events, 5);
    eprintln!("bench: allocation counting ping-pong...");
    let apm = allocs_per_message(2000);
    eprintln!(
        "bench: event-engine scale cell ({} ranks)...",
        EVENT_BENCH_RANKS
    );
    let erps = event_ranks_per_s(&run)?;
    Ok(BenchEntry {
        label: label.to_string(),
        smoke_cells_per_s_median: median,
        smoke_cells_per_s_p90: p90,
        smoke_cells: n_cells,
        smoke_reps: reps,
        events_per_s: 1.0 / trace_cost,
        ns_per_hook_dispatch: hook_cost * 1e9,
        allocs_per_message: apm,
        event_ranks_per_s: erps,
        smoke_samples: moments.count() as usize,
        smoke_cells_per_s_mean: moments.mean(),
        smoke_cells_per_s_m2: moments.m2(),
        gate_verdict: String::new(),
    })
}

/// Human-readable comparison of the trajectory (last entry vs. its
/// predecessor when there is one).
pub fn render_report(entries: &[BenchEntry]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<24} {:>14} {:>14} {:>12} {:>14} {:>12} {:>13}\n",
        "label", "cells/s med", "cells/s p90", "Mevents/s", "ns/dispatch", "allocs/msg", "evt ranks/s"
    ));
    for e in entries {
        out.push_str(&format!(
            "{:<24} {:>14.3} {:>14.3} {:>12.2} {:>14.1} {:>12.1} {:>13.1}\n",
            e.label,
            e.smoke_cells_per_s_median,
            e.smoke_cells_per_s_p90,
            e.events_per_s / 1e6,
            e.ns_per_hook_dispatch,
            e.allocs_per_message,
            e.event_ranks_per_s
        ));
    }
    if entries.len() >= 2 {
        let prev = &entries[entries.len() - 2];
        let last = &entries[entries.len() - 1];
        if prev.smoke_cells_per_s_median > 0.0 {
            out.push_str(&format!(
                "throughput: {:.2}x vs previous entry ('{}' -> '{}')\n",
                last.smoke_cells_per_s_median / prev.smoke_cells_per_s_median,
                prev.label,
                last.label
            ));
        }
    }
    out
}

/// The gate decision for a fresh run vs. the committed baseline.
///
/// When both entries carry throughput moments, the drop/gain must be
/// **statistically significant** under Welch's t-test (the same test
/// `repro diff` applies to profile metrics) before the verdict moves off
/// `NoChange` — a noisy CI runner no longer trips the gate on an
/// insignificant wobble, and a real significant drop is flagged even
/// when the median heuristic would have let it slide. `Regressed`
/// additionally requires the mean to fall past the
/// [`REGRESSION_TOLERANCE`] floor. Entries committed before the moments
/// existed (zero `smoke_samples`) fall back to the original median
/// heuristic.
pub fn gate_verdict(committed: &BenchEntry, fresh: &BenchEntry) -> DiffVerdict {
    if committed.smoke_samples >= 2 && fresh.smoke_samples >= 2 {
        let sig = welch_from_moments(
            committed.smoke_samples as u64,
            committed.smoke_cells_per_s_mean,
            committed.smoke_cells_per_s_m2,
            fresh.smoke_samples as u64,
            fresh.smoke_cells_per_s_mean,
            fresh.smoke_cells_per_s_m2,
        );
        if !sig.significant {
            return DiffVerdict::NoChange;
        }
        let floor = committed.smoke_cells_per_s_mean * (1.0 - REGRESSION_TOLERANCE);
        if fresh.smoke_cells_per_s_mean < floor {
            return DiffVerdict::Regressed;
        }
        if fresh.smoke_cells_per_s_mean > committed.smoke_cells_per_s_mean {
            return DiffVerdict::Improved;
        }
        return DiffVerdict::NoChange;
    }
    // Median heuristic for moment-less baselines.
    let floor = committed.smoke_cells_per_s_median * (1.0 - REGRESSION_TOLERANCE);
    let ceil = committed.smoke_cells_per_s_median * (1.0 + REGRESSION_TOLERANCE);
    if fresh.smoke_cells_per_s_median < floor {
        DiffVerdict::Regressed
    } else if fresh.smoke_cells_per_s_median > ceil {
        DiffVerdict::Improved
    } else {
        DiffVerdict::NoChange
    }
}

/// The `--check` gate: fails (nonzero exit) exactly when [`gate_verdict`]
/// says `Regressed`.
pub fn check_regression(committed: &BenchEntry, fresh: &BenchEntry) -> Result<()> {
    if gate_verdict(committed, fresh) != DiffVerdict::Regressed {
        return Ok(());
    }
    if committed.smoke_samples >= 2 && fresh.smoke_samples >= 2 {
        let sig = welch_from_moments(
            committed.smoke_samples as u64,
            committed.smoke_cells_per_s_mean,
            committed.smoke_cells_per_s_m2,
            fresh.smoke_samples as u64,
            fresh.smoke_cells_per_s_mean,
            fresh.smoke_cells_per_s_m2,
        );
        bail!(
            "perf regression: mean cell throughput {:.3} cells/s fell significantly \
             below committed '{}' = {:.3} (Welch t = {:.2}, df = {:.1}, \
             {}% drop tolerance)",
            fresh.smoke_cells_per_s_mean,
            committed.label,
            committed.smoke_cells_per_s_mean,
            sig.t,
            sig.df,
            (REGRESSION_TOLERANCE * 100.0) as u32
        );
    }
    bail!(
        "perf regression: median cell throughput {:.3} cells/s is below the \
         gate floor {:.3} ({}% drop tolerance vs committed '{}' = {:.3})",
        fresh.smoke_cells_per_s_median,
        committed.smoke_cells_per_s_median * (1.0 - REGRESSION_TOLERANCE),
        (REGRESSION_TOLERANCE * 100.0) as u32,
        committed.label,
        committed.smoke_cells_per_s_median
    );
}

/// Entry point for `repro bench`.
///
/// ```text
/// repro bench [--json BENCH_v1.json] [--label L] [--append]
///             [--check] [--report FILE] [--reps N] [--full]
/// ```
pub fn run_bench(args: &Args) -> Result<()> {
    let json_path = args.get_or("json", "BENCH_v1.json").to_string();
    let label = args.get_or("label", "current").to_string();
    let reps = args.get_usize("reps", 3);
    let full = args.has("full");

    let mut entries: Vec<BenchEntry> = match std::fs::read_to_string(&json_path) {
        Ok(text) => parse_bench_file(&text)
            .with_context(|| format!("reading committed bench file {}", json_path))?,
        Err(_) => Vec::new(),
    };
    let committed_last = entries.last().cloned();

    let mut fresh = run_suite(&label, full, reps)?;
    if let Some(committed) = &committed_last {
        // Stamp the verdict into the entry, so the appended trajectory
        // records how each run compared to its baseline — and so
        // `repro diff --bench` can re-render the decision later.
        fresh.gate_verdict = gate_verdict(committed, &fresh).name().to_string();
        println!(
            "bench gate verdict vs committed '{}': {}",
            committed.label, fresh.gate_verdict
        );
    }
    println!("{}", render_report(std::slice::from_ref(&fresh)));

    if args.has("check") {
        let committed = committed_last.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "--check needs a committed bench file with at least one entry ({})",
                json_path
            )
        })?;
        check_regression(committed, &fresh)?;
        println!(
            "perf gate OK: {:.3} cells/s vs committed {:.3} ('{}'), tolerance {}%",
            fresh.smoke_cells_per_s_median,
            committed.smoke_cells_per_s_median,
            committed.label,
            (REGRESSION_TOLERANCE * 100.0) as u32
        );
    }

    if args.has("append") {
        entries.push(fresh.clone());
        std::fs::write(&json_path, render_bench_file(&entries))
            .with_context(|| format!("writing {}", json_path))?;
        println!("appended entry '{}' to {}", label, json_path);
    }

    if let Some(report_path) = args.get("report") {
        let mut all = entries.clone();
        if !args.has("append") {
            all.push(fresh.clone());
        }
        std::fs::write(report_path, render_report(&all))
            .with_context(|| format!("writing {}", report_path))?;
        println!("comparison report written to {}", report_path);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(label: &str, median: f64) -> BenchEntry {
        BenchEntry {
            label: label.to_string(),
            smoke_cells_per_s_median: median,
            smoke_cells_per_s_p90: median * 1.2,
            smoke_cells: 6,
            smoke_reps: 3,
            events_per_s: 1e7,
            ns_per_hook_dispatch: 25.0,
            allocs_per_message: 4.0,
            event_ranks_per_s: 900.0,
            // moment-less: routes gate_verdict to the median fallback
            smoke_samples: 0,
            smoke_cells_per_s_mean: median,
            smoke_cells_per_s_m2: 0.0,
            gate_verdict: String::new(),
        }
    }

    /// An entry carrying Welch moments: `n` samples, the given mean and M2.
    fn moments(label: &str, mean: f64, m2: f64, n: usize) -> BenchEntry {
        let mut e = entry(label, mean);
        e.smoke_samples = n;
        e.smoke_cells_per_s_mean = mean;
        e.smoke_cells_per_s_m2 = m2;
        e
    }

    #[test]
    fn json_roundtrip_preserves_entries() {
        let mut second = moments("pooled", 3.2, 0.25, 36);
        second.gate_verdict = "improved".to_string();
        let entries = vec![entry("baseline", 1.5), second];
        let text = render_bench_file(&entries);
        let back = parse_bench_file(&text).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].label, "baseline");
        assert!((back[1].smoke_cells_per_s_median - 3.2).abs() < 1e-12);
        assert_eq!(back[1].smoke_cells, 6);
        assert!((back[0].event_ranks_per_s - 900.0).abs() < 1e-12);
        // Welch moments + verdict survive the roundtrip.
        assert_eq!(back[1].smoke_samples, 36);
        assert!((back[1].smoke_cells_per_s_m2 - 0.25).abs() < 1e-12);
        assert_eq!(back[1].gate_verdict, "improved");
        // Entries written before the moments existed parse with zeros
        // (same tolerance as event_ranks_per_s below).
        assert_eq!(back[0].smoke_samples, 0);
        assert_eq!(back[0].gate_verdict, "");
    }

    #[test]
    fn pre_event_engine_entries_parse_with_zero_ranks_per_s() {
        // Entries committed before the event engine have no
        // event_ranks_per_s field; they must still parse.
        let mut j = entry("old", 1.0).to_json();
        let Json::Obj(map) = &mut j else { unreachable!() };
        map.remove("event_ranks_per_s");
        let text = format!(
            "{{\"schema\":\"{}\",\"entries\":[{}]}}",
            BENCH_SCHEMA,
            j.to_string_pretty()
        );
        let back = parse_bench_file(&text).unwrap();
        assert_eq!(back[0].event_ranks_per_s, 0.0);
    }

    #[test]
    fn schema_mismatch_rejected() {
        assert!(parse_bench_file("{\"schema\":\"BENCH_v0\",\"entries\":[]}").is_err());
        assert!(parse_bench_file("{\"entries\":[]}").is_err());
    }

    #[test]
    fn regression_gate_triggers_past_tolerance() {
        // Moment-less entries: the original median heuristic.
        let base = entry("baseline", 10.0);
        // 10% drop: within the 15% tolerance
        assert!(check_regression(&base, &entry("pr", 9.0)).is_ok());
        assert_eq!(gate_verdict(&base, &entry("pr", 9.0)), DiffVerdict::NoChange);
        // 20% drop: gate fires
        assert!(check_regression(&base, &entry("pr", 8.0)).is_err());
        assert_eq!(gate_verdict(&base, &entry("pr", 8.0)), DiffVerdict::Regressed);
        // 20% gain: reported as improved (exit code 3, still passing)
        assert_eq!(gate_verdict(&base, &entry("pr", 12.0)), DiffVerdict::Improved);
    }

    #[test]
    fn welch_gate_flags_a_significant_drop() {
        // Tight distributions (variance 0.01 over 12 samples): a halving
        // is unambiguous.
        let base = moments("baseline", 10.0, 0.11, 12);
        let fresh = moments("pr", 5.0, 0.11, 12);
        assert_eq!(gate_verdict(&base, &fresh), DiffVerdict::Regressed);
        let err = format!("{:#}", check_regression(&base, &fresh).unwrap_err());
        assert!(err.contains("Welch t ="), "{}", err);
        // ...and a significant gain is improvement, not regression.
        let faster = moments("pr", 20.0, 0.11, 12);
        assert_eq!(gate_verdict(&base, &faster), DiffVerdict::Improved);
    }

    #[test]
    fn welch_gate_passes_noise_the_median_heuristic_would_fail() {
        // Wide distributions (variance 100 over 12 samples): a 20% mean
        // drop is indistinguishable from noise (t ≈ 0.49). The old
        // median-only gate would have failed this run; the Welch gate
        // correctly reports no change.
        let base = moments("baseline", 10.0, 1100.0, 12);
        let fresh = moments("pr", 8.0, 1100.0, 12);
        assert_eq!(gate_verdict(&base, &fresh), DiffVerdict::NoChange);
        assert!(check_regression(&base, &fresh).is_ok());
        // The same medians without moments DO fail — the fallback is the
        // old behavior, bit for bit.
        assert!(check_regression(&entry("baseline", 10.0), &entry("pr", 8.0)).is_err());
    }

    #[test]
    fn smoke_matrix_selection_covers_apps_and_stays_small() {
        let cells = smoke_cells();
        assert!(!cells.is_empty());
        assert!(cells.iter().all(|c| c.nranks <= SMOKE_MAX_RANKS));
        // every app appears at least once in the bench slice
        for app in [
            crate::benchpark::AppKind::Amg2023,
            crate::benchpark::AppKind::Kripke,
            crate::benchpark::AppKind::Laghos,
        ] {
            assert!(cells.iter().any(|c| c.app == app), "{:?} missing", app);
        }
    }

    #[test]
    fn report_shows_trajectory_speedup() {
        let txt = render_report(&[entry("baseline", 2.0), entry("opt", 5.0)]);
        assert!(txt.contains("2.50x"), "{}", txt);
    }
}
