//! Regenerators for every table and figure in the paper's evaluation.
//! Each function renders text (tables / ASCII charts) and, when given an
//! output directory, drops the matching CSV next to it.

use std::path::Path;

use anyhow::Result;

use crate::benchpark::system::SystemId;
use crate::benchpark::table3_matrix;
use crate::caliper::{attr, RunProfile};
use crate::thicket::export::{write_matrix_csv, write_series_csv};
use crate::thicket::{stats, Thicket};
use crate::util::plotascii::{Chart, Heatmap, Series};
use crate::util::table::{sci, Align, TextTable};

/// Render every table and figure into one report string; when `out` is
/// given, drop each figure's CSV there too. Any emitter error propagates —
/// the CI campaign-smoke job gates on this returning `Ok`.
pub fn render_all(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    let mut all = String::new();
    all.push_str(&table1());
    all.push_str(&table2());
    all.push_str(&table3());
    all.push_str(&table4(thicket));
    all.push_str(&fig1(thicket, out)?);
    all.push_str(&fig2(thicket, out)?);
    all.push_str(&fig3(thicket, out)?);
    all.push_str(&fig4(thicket, out)?);
    all.push_str(&fig5(thicket, out)?);
    all.push_str(&fig6(thicket, out)?);
    all.push_str(&comm_heatmap(thicket, out)?);
    all.push_str(&fig7(thicket, out)?);
    all.push_str(&fig8(thicket, out)?);
    all.push_str(&fig9(thicket, out)?);
    Ok(all)
}

/// The canonical communication region per app — where the `comm-matrix`
/// channel shows the pattern structure (neighbor bands for the halo apps,
/// the dense far-field exchange for zmodel).
fn halo_region_for(app: &str) -> &'static str {
    match app {
        "amg2023" => "matvec_comm_level_0",
        "kripke" => "sweep_comm",
        "laghos" => "halo_exchange",
        "zmodel" => "br_exchange",
        _ => "halo_exchange",
    }
}

/// Smallest run in `group` carrying a comm matrix (smallest = clearest
/// structure): the preferred region's matrix if recorded, else the first
/// region with one. Shared by the heatmap figures.
fn first_matrix_run<'t>(
    group: &'t Thicket,
    preferred: &str,
) -> Option<(&'t RunProfile, String, Vec<Vec<f64>>)> {
    for run in group.by_ranks() {
        let dense = stats::comm_matrix_dense(run, preferred)
            .or_else(|| stats::first_region_with_matrix(run));
        if let Some((path, matrix)) = dense {
            return Some((run, path, matrix));
        }
    }
    None
}

/// Rank×rank sent-bytes heatmap per (app, system) from the `comm-matrix`
/// channel, using each group's smallest run (the clearest structure).
/// Requires profiles recorded with `--channels ...,comm-matrix`; groups
/// without matrix data are skipped, and an explanatory line is emitted
/// when no group has any.
pub fn comm_heatmap(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    if thicket.with_comm_matrix().is_empty() {
        return Ok(
            "comm-matrix heatmap: no profile carries the comm-matrix channel \
             (re-run the campaign with --channels comm-stats,comm-matrix)\n"
                .to_string(),
        );
    }
    let mut text = String::new();
    for (key, group) in group_app_system(thicket) {
        let meta_of = |k: &str| {
            group
                .runs
                .first()
                .and_then(|r| r.meta.get(k).cloned())
                .unwrap_or_default()
        };
        let (app, system) = (meta_of("app"), meta_of("system"));
        let (run, path, matrix) = match first_matrix_run(&group, halo_region_for(&app)) {
            Some(f) => f,
            None => continue,
        };
        let ranks = run.meta.get("ranks").cloned().unwrap_or_default();
        if let Some(dir) = out {
            write_matrix_csv(dir.join(format!("heatmap_{}_{}.csv", app, system)), &matrix)?;
        }
        let title = format!(
            "comm-matrix heatmap — {} @ {} ranks, region '{}' (bytes sent)",
            key, ranks, path
        );
        let hm = Heatmap::new(&title, "dst rank", "src rank");
        text.push_str(&hm.render(&matrix));
        text.push('\n');
    }
    Ok(text)
}

/// Table I — the attributes the comm-pattern profiler collects.
pub fn table1() -> String {
    let mut t = TextTable::new(&["Attribute", "Description"])
        .align(0, Align::Left)
        .align(1, Align::Left)
        .title("TABLE I — MPI attributes collected by Caliper comm regions");
    for (name, desc) in attr::TABLE1 {
        t.row(vec![name.to_string(), desc.to_string()]);
    }
    t.render()
}

/// Table II — the two systems.
pub fn table2() -> String {
    let mut t = TextTable::new(&["Hardware Attribute", "Tioga", "Dane"])
        .align(0, Align::Left)
        .title("TABLE II — Architectures used for the experiments");
    let tioga = SystemId::Tioga.table2_row();
    let dane = SystemId::Dane.table2_row();
    for i in 0..tioga.len() {
        t.row(vec![
            tioga[i].0.to_string(),
            tioga[i].1.to_string(),
            dane[i].1.to_string(),
        ]);
    }
    t.render()
}

/// Table III — the experiment matrix.
pub fn table3() -> String {
    let mut t = TextTable::new(&["Benchmark", "System", "Scaling", "# Processes", "Dimensions"])
        .align(0, Align::Left)
        .align(1, Align::Left)
        .align(2, Align::Left)
        .title("TABLE III — Experiments run for each benchmark");
    for spec in table3_matrix() {
        use crate::benchpark::AppKind;
        // 2D surface/mesh apps decompose over a 2D process grid.
        let dims = if matches!(spec.app, AppKind::Laghos | AppKind::Zmodel) {
            let d = spec.pdims2();
            format!("{}x{}", d[0], d[1])
        } else {
            let d = spec.pdims3();
            format!("{}x{}x{}", d[0], d[1], d[2])
        };
        t.row(vec![
            spec.app.name().to_string(),
            spec.system.name().to_string(),
            spec.scaling.name().to_string(),
            spec.nranks.to_string(),
            dims,
        ]);
    }
    t.render()
}

/// Table IV — sample metric collection from annotated regions.
pub fn table4(thicket: &Thicket) -> String {
    let mut t = TextTable::new(&[
        "Application and Processes",
        "Total Bytes Sent",
        "Total Sends",
        "Largest Send (bytes)",
        "Avg Send Size (bytes)",
    ])
    .align(0, Align::Left)
    .title("TABLE IV — Metric collection from annotated application regions");
    for run in thicket.by_ranks() {
        // stable ordering: laghos, kripke dane/tioga, amg dane/tioga —
        // follow the thicket's (app, system) grouping instead.
        let _ = run;
    }
    for (group_key, group) in group_app_system(thicket) {
        for run in group.by_ranks() {
            let (bytes, sends, largest, avg) = stats::table4_row(run);
            t.row(vec![
                format!("{} - {}", group_key, run.meta["ranks"]),
                sci(bytes),
                sci(sends),
                largest.to_string(),
                sci(avg),
            ]);
        }
    }
    t.render()
}

fn group_app_system(thicket: &Thicket) -> Vec<(String, Thicket)> {
    let mut out = Vec::new();
    for (app, by_app) in thicket.groupby("app") {
        for (system, group) in by_app.groupby("system") {
            out.push((format!("{} ({})", app, system), group));
        }
    }
    out
}

fn render_time_chart(
    title: &str,
    group: &Thicket,
    regions: &[&str],
    out: Option<(&Path, String)>,
) -> Result<String> {
    let mut series = Vec::new();
    let mut csv = Vec::new();
    for name in regions {
        let pts = group.series(|r| stats::region_time_avg(r, name));
        if !pts.is_empty() {
            series.push(Series::new(name, pts.clone()));
            csv.push((name.to_string(), pts));
        }
    }
    if let Some((dir, file)) = out {
        write_series_csv(dir.join(file), &csv, "ranks", "avg_time_per_rank_s")?;
    }
    let chart = Chart::new(title, "processes", "avg time per rank (s)").log_y();
    Ok(chart.render(&series))
}

/// Fig 1 — Kripke average time per rank (main, solve, sweep_comm), both
/// systems.
pub fn fig1(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    let mut text = String::new();
    for system in ["dane", "tioga"] {
        let group = thicket.filter(&[("app", "kripke"), ("system", system)]);
        if group.is_empty() {
            continue;
        }
        let title = format!("Fig 1 — Kripke weak scaling, avg time/rank ({})", system);
        text.push_str(&render_time_chart(
            &title,
            &group,
            &["main", "solve", "sweep_comm", "pop_reduce"],
            out.map(|d| (d, format!("fig1_kripke_{}.csv", system))),
        )?);
        text.push('\n');
    }
    Ok(text)
}

/// Fig 2 — AMG bytes sent per process per MG level.
pub fn fig2(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    amg_level_figure(
        thicket,
        out,
        "fig2",
        "bytes sent per process (max)",
        |reg| reg.bytes_sent.max(),
    )
}

/// Fig 3 — AMG average source ranks per MG level.
pub fn fig3(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    amg_level_figure(
        thicket,
        out,
        "fig3",
        "avg distinct source ranks",
        |reg| reg.src_ranks.avg(),
    )
}

fn amg_level_figure(
    thicket: &Thicket,
    out: Option<&Path>,
    fig: &str,
    y_label: &str,
    metric: impl Fn(&crate::caliper::AggRegion) -> f64 + Copy,
) -> Result<String> {
    let mut text = String::new();
    for system in ["dane", "tioga"] {
        let group = thicket.filter(&[("app", "amg2023"), ("system", system)]);
        if group.is_empty() {
            continue;
        }
        // level → series over rank counts
        let mut by_level: std::collections::BTreeMap<usize, Vec<(f64, f64)>> = Default::default();
        for run in group.by_ranks() {
            let ranks = run.meta_usize("ranks").unwrap_or(0) as f64;
            for (level, v) in stats::amg_per_level(run, metric) {
                by_level.entry(level).or_default().push((ranks, v));
            }
        }
        let series: Vec<Series> = by_level
            .iter()
            .map(|(l, pts)| Series::new(&format!("MG level {}", l), pts.clone()))
            .collect();
        let csv: Vec<(String, Vec<(f64, f64)>)> = by_level
            .iter()
            .map(|(l, pts)| (format!("level_{}", l), pts.clone()))
            .collect();
        if let Some(dir) = out {
            write_series_csv(
                dir.join(format!("{}_amg_{}.csv", fig, system)),
                &csv,
                "ranks",
                y_label,
            )?;
        }
        let title = format!(
            "{} — AMG2023 {}, per MG level ({})",
            fig, y_label, system
        );
        let chart = Chart::new(&title, "processes", y_label).log_y();
        text.push_str(&chart.render(&series));
        text.push('\n');
    }
    Ok(text)
}

/// Fig 4 — Laghos average time per rank per region (Dane, strong scaling).
pub fn fig4(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    let group = thicket.filter(&[("app", "laghos"), ("system", "dane")]);
    if group.is_empty() {
        return Ok("fig4: no laghos runs in thicket\n".to_string());
    }
    render_time_chart(
        "Fig 4 — Laghos strong scaling, avg time/rank (dane)",
        &group,
        &["main", "timestep", "halo_exchange", "reduction", "broadcast"],
        out.map(|d| (d, "fig4_laghos_dane.csv".to_string())),
    )
}

/// Fig 5 — bandwidth and message rate per process, all apps, Dane.
pub fn fig5(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    bw_rate_figure(thicket, out, "fig5", "dane", &["amg2023", "kripke", "laghos"])
}

/// Fig 6 — bandwidth and message rate per process, AMG + Kripke, Tioga.
pub fn fig6(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    bw_rate_figure(thicket, out, "fig6", "tioga", &["amg2023", "kripke"])
}

fn bw_rate_figure(
    thicket: &Thicket,
    out: Option<&Path>,
    fig: &str,
    system: &str,
    apps: &[&str],
) -> Result<String> {
    let mut text = String::new();
    for (metric_name, f) in [
        (
            "bytes/sec/process",
            stats::bandwidth_per_proc as fn(&crate::caliper::RunProfile) -> Option<f64>,
        ),
        ("messages/sec/process", stats::message_rate_per_proc),
    ] {
        let mut series = Vec::new();
        let mut csv = Vec::new();
        for app in apps {
            let group = thicket.filter(&[("app", app), ("system", system)]);
            let pts = group.series(f);
            if !pts.is_empty() {
                series.push(Series::new(app, pts.clone()));
                csv.push((app.to_string(), pts));
            }
        }
        if series.is_empty() {
            continue;
        }
        if let Some(dir) = out {
            let fname = format!(
                "{}_{}_{}.csv",
                fig,
                system,
                metric_name.replace('/', "_per_")
            );
            write_series_csv(dir.join(fname), &csv, "ranks", metric_name)?;
        }
        let title = format!("{} — {} ({})", fig, metric_name, system);
        let chart = Chart::new(&title, "processes", metric_name).log_y();
        text.push_str(&chart.render(&series));
        text.push('\n');
    }
    Ok(text)
}

/// Fraction of the n×n off-diagonal cells carrying traffic — 1.0 for a
/// fully dense all-to-all, small for a banded halo.
fn matrix_fill(matrix: &[Vec<f64>]) -> f64 {
    let n = matrix.len();
    if n < 2 {
        return 0.0;
    }
    let nonzero = matrix
        .iter()
        .enumerate()
        .flat_map(|(s, row)| row.iter().enumerate().filter(move |(d, _)| *d != s))
        .filter(|(_, v)| **v > 0.0)
        .count();
    nonzero as f64 / (n * (n - 1)) as f64
}

/// Fig 7 — global vs halo communication structure: zmodel's dense
/// rank×rank far-field/transpose matrix side by side with AMG's banded
/// halo matrix, each annotated with its off-diagonal fill factor. This is
/// the Beatnik argument in one picture: the pattern class a
/// halo-dominated suite never exercises.
pub fn fig7(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    let mut text = String::new();
    let mut fills = Vec::new();
    for app in ["zmodel", "amg2023"] {
        let group = thicket.filter(&[("app", app)]);
        let (run, path, matrix) = match first_matrix_run(&group, halo_region_for(app)) {
            Some(f) => f,
            None => {
                text.push_str(&format!(
                    "fig7: no {} profile carries the comm-matrix channel \
                     (re-run the campaign with --channels comm-stats,comm-matrix)\n",
                    app
                ));
                continue;
            }
        };
        let ranks = run.meta.get("ranks").cloned().unwrap_or_default();
        let system = run.meta.get("system").cloned().unwrap_or_default();
        let fill = matrix_fill(&matrix);
        fills.push((app, fill));
        if let Some(dir) = out {
            write_matrix_csv(dir.join(format!("fig7_{}_{}.csv", app, system)), &matrix)?;
        }
        let title = format!(
            "Fig 7 — {} @ {} ranks ({}), region '{}': off-diagonal fill {:.0}%",
            app,
            ranks,
            system,
            path,
            fill * 100.0
        );
        let hm = Heatmap::new(&title, "dst rank", "src rank");
        text.push_str(&hm.render(&matrix));
        text.push('\n');
    }
    if let [(_, zfill), (_, afill)] = fills[..] {
        text.push_str(&format!(
            "fig7: zmodel fills {:.0}% of the rank×rank matrix vs {:.0}% for \
             AMG's halo — global vs neighborhood communication.\n",
            zfill * 100.0,
            afill * 100.0
        ));
    }
    Ok(text)
}

/// Fig 8 — Waitall wait-vs-transfer breakdown for each app's canonical
/// communication region, from the `mpi-time` channel's completion split:
/// *wait* is time a rank spent blocked before the critical message's wire
/// transfer began (partner not ready, receive posted late, rendezvous
/// handshake), *transfer* the data-movement remainder. This is the paper's
/// headline per-function view — halo time concentrated in
/// `MPI_Waitall`/`MPI_Irecv` waiting, not byte movement — which an
/// eager-only simulator could never produce.
pub fn fig8(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    let mut text = String::new();
    let mut any = false;
    for (key, group) in group_app_system(thicket) {
        let meta_of = |k: &str| {
            group
                .runs
                .first()
                .and_then(|r| r.meta.get(k).cloned())
                .unwrap_or_default()
        };
        let (app, system) = (meta_of("app"), meta_of("system"));
        let region = halo_region_for(&app);
        let wait = group.series(|r| stats::region_mpi_wait_avg(r, region));
        let transfer = group.series(|r| stats::region_mpi_transfer_avg(r, region));
        if wait.is_empty() && transfer.is_empty() {
            continue;
        }
        any = true;
        let mut series = Vec::new();
        let mut csv = Vec::new();
        for (name, pts) in [("wait", wait), ("transfer", transfer)] {
            if !pts.is_empty() {
                series.push(Series::new(name, pts.clone()));
                csv.push((name.to_string(), pts));
            }
        }
        if let Some(dir) = out {
            write_series_csv(
                dir.join(format!("fig8_{}_{}.csv", app, system)),
                &csv,
                "ranks",
                "avg_seconds_per_rank",
            )?;
        }
        let title = format!(
            "Fig 8 — {} region '{}': Waitall wait vs transfer (avg s/rank)",
            key, region
        );
        let chart = Chart::new(&title, "processes", "avg seconds per rank").log_y();
        text.push_str(&chart.render(&series));
        text.push('\n');
    }
    if !any {
        return Ok(
            "fig8: no profile carries the mpi-time channel's wait breakdown \
             (re-run the campaign with --channels comm-stats,mpi-time)\n"
                .to_string(),
        );
    }
    Ok(text)
}

/// Fig 9 — per-region critical-path share vs. rank count, from the
/// `trace` channel's happens-before analysis: for each (app, system)
/// group, which regions own the dependency chain that bounds wall time,
/// and how that ownership shifts as the job scales. This is the view the
/// aggregate profiler cannot produce — a region can dominate total MPI
/// time yet sit entirely off the critical path.
pub fn fig9(thicket: &Thicket, out: Option<&Path>) -> Result<String> {
    let mut text = String::new();
    let mut any = false;
    for (key, group) in group_app_system(thicket) {
        let meta_of = |k: &str| {
            group
                .runs
                .first()
                .and_then(|r| r.meta.get(k).cloned())
                .unwrap_or_default()
        };
        let (app, system) = (meta_of("app"), meta_of("system"));
        // Regions carrying critical-path attribution anywhere in the group.
        let mut region_names: Vec<String> = Vec::new();
        for run in group.by_ranks() {
            for (path, reg) in &run.regions {
                if reg.trace.map(|t| t.critpath > 0.0).unwrap_or(false)
                    && !region_names.contains(path)
                {
                    region_names.push(path.clone());
                }
            }
        }
        if region_names.is_empty() {
            continue;
        }
        any = true;
        let mut series = Vec::new();
        let mut csv = Vec::new();
        for name in &region_names {
            let pts = group.series(|r| stats::region_critpath_frac(r, name));
            if !pts.is_empty() {
                series.push(Series::new(name, pts.clone()));
                csv.push((name.clone(), pts));
            }
        }
        if let Some(dir) = out {
            write_series_csv(
                dir.join(format!("fig9_{}_{}.csv", app, system)),
                &csv,
                "ranks",
                "critpath_fraction",
            )?;
        }
        let title = format!("Fig 9 — {}: per-region critical-path share", key);
        let chart = Chart::new(&title, "processes", "fraction of critical path");
        text.push_str(&chart.render(&series));
        text.push('\n');
    }
    if !any {
        return Ok(
            "fig9: no profile carries the trace channel's critical-path \
             attribution (re-run the campaign with --channels comm-stats,trace)\n"
                .to_string(),
        );
    }
    Ok(text)
}

/// ASCII Gantt timeline over a cell's trace artifact (`repro trace`):
/// per-rank lanes of compute / blocked-wait / transfer / collective
/// states. Thin wrapper so every figure surface lives in this module.
pub fn trace_gantt(trace: &crate::trace::RunTrace, width: usize) -> String {
    crate::trace::gantt::render(trace, width)
}

/// Textual trace analysis (`repro trace`): wait-state classification
/// totals per kind and the region-attributed critical path.
pub fn trace_report(trace: &crate::trace::RunTrace) -> String {
    use crate::trace::{classify, critical_path, WaitKind};
    use crate::util::duration::fmt_duration;
    let mut out = String::new();
    let states = classify(trace);
    let mut t = TextTable::new(&["Wait state", "Instances", "Idle time", "Worst single"])
        .align(0, Align::Left)
        .title("wait-state classification");
    for kind in [
        WaitKind::LateSender,
        WaitKind::LateReceiver,
        WaitKind::WaitAtCollective,
    ] {
        let of_kind: Vec<_> = states.iter().filter(|s| s.kind == kind).collect();
        let total: f64 = of_kind.iter().map(|s| s.duration).sum();
        let worst = of_kind.iter().map(|s| s.duration).fold(0.0, f64::max);
        t.row(vec![
            kind.name().to_string(),
            of_kind.len().to_string(),
            fmt_duration(total),
            fmt_duration(worst),
        ]);
    }
    out.push_str(&t.render());
    out.push('\n');
    match critical_path(trace) {
        Some(cp) => {
            out.push_str(&format!(
                "critical path: {} end-to-end (ends on rank {}, {} cross-rank \
                 hop{}, {} in gated communication)\n",
                fmt_duration(cp.total),
                cp.end_rank,
                cp.hops,
                if cp.hops == 1 { "" } else { "s" },
                fmt_duration(cp.comm_seconds),
            ));
            let mut t = TextTable::new(&["Region", "On critical path", "Share"])
                .align(0, Align::Left)
                .title("critical-path attribution per region");
            // Largest share first; ties by path for determinism.
            let mut rows: Vec<(&String, &f64)> = cp.per_region.iter().collect();
            rows.sort_by(|a, b| b.1.total_cmp(a.1).then(a.0.cmp(b.0)));
            for (region, secs) in rows {
                t.row(vec![
                    region.clone(),
                    fmt_duration(*secs),
                    format!("{:.1}%", 100.0 * secs / cp.total.max(f64::MIN_POSITIVE)),
                ]);
            }
            out.push_str(&t.render());
        }
        None => out.push_str("critical path: trace is empty\n"),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_tables_render() {
        let t1 = table1();
        assert!(t1.contains("sends"));
        assert!(t1.contains("Coll") || t1.contains("colls"));
        let t2 = table2();
        assert!(t2.contains("MI250X"));
        assert!(t2.contains("Sapphire"));
        let t3 = table3();
        assert!(t3.contains("kripke"));
        assert!(t3.contains("8x8x8"));
        assert!(t3.contains("896"));
    }

    #[test]
    fn comm_heatmap_renders_matrix_or_explains() {
        use crate::caliper::{AggCommMatrix, AggRegion, RunProfile};
        // no matrix data → explanatory line
        let empty = Thicket::new(vec![]);
        let txt = comm_heatmap(&empty, None).unwrap();
        assert!(txt.contains("--channels"), "{}", txt);

        // AMG run with a matrix on the halo region → heatmap
        let mut run = RunProfile::default();
        run.meta.insert("app".into(), "amg2023".into());
        run.meta.insert("system".into(), "dane".into());
        run.meta.insert("ranks".into(), "8".into());
        let mut reg = AggRegion {
            is_comm_region: true,
            ..Default::default()
        };
        let mut m = AggCommMatrix::default();
        for src in 0..8usize {
            let dst = (src + 1) % 8;
            m.sent.insert((src, dst), (10, 1024));
            m.recv.insert((src, dst), (10, 1024));
        }
        reg.comm_matrix = Some(m);
        run.regions.insert("main/matvec_comm_level_0".into(), reg);
        let t = Thicket::new(vec![run]);
        let txt = comm_heatmap(&t, None).unwrap();
        assert!(txt.contains("amg2023"), "{}", txt);
        assert!(txt.contains("matvec_comm_level_0"), "{}", txt);
        assert!(txt.contains("src rank"), "{}", txt);
    }

    #[test]
    fn fig7_contrasts_dense_zmodel_with_banded_amg() {
        use crate::caliper::{AggCommMatrix, AggRegion, RunProfile};
        // without matrices: explanatory lines for both apps
        let txt = fig7(&Thicket::new(vec![]), None).unwrap();
        assert!(txt.contains("no zmodel profile"), "{}", txt);
        assert!(txt.contains("no amg2023 profile"), "{}", txt);

        let mk = |app: &str, region: &str, dense: bool| {
            let mut run = RunProfile::default();
            run.meta.insert("app".into(), app.into());
            run.meta.insert("system".into(), "tioga".into());
            run.meta.insert("ranks".into(), "4".into());
            let mut reg = AggRegion {
                is_comm_region: true,
                ..Default::default()
            };
            let mut m = AggCommMatrix::default();
            for src in 0..4usize {
                for dst in 0..4usize {
                    if src == dst || (!dense && dst != (src + 1) % 4) {
                        continue;
                    }
                    m.sent.insert((src, dst), (1, 100));
                    m.recv.insert((src, dst), (1, 100));
                }
            }
            reg.comm_matrix = Some(m);
            run.regions.insert(format!("main/{}", region), reg);
            run
        };
        let t = Thicket::new(vec![
            mk("zmodel", "br_exchange", true),
            mk("amg2023", "matvec_comm_level_0", false),
        ]);
        let txt = fig7(&t, None).unwrap();
        assert!(txt.contains("fill 100%"), "{}", txt);
        assert!(txt.contains("fill 33%"), "{}", txt);
        assert!(txt.contains("global vs neighborhood"), "{}", txt);
    }

    #[test]
    fn fig8_renders_wait_breakdown_or_explains() {
        use crate::caliper::{AggMetric, AggRegion, RunProfile};
        // no mpi-time split anywhere: explanatory line
        let txt = fig8(&Thicket::new(vec![]), None).unwrap();
        assert!(txt.contains("mpi-time"), "{}", txt);

        let mk = |ranks: usize| {
            let mut run = RunProfile::default();
            run.meta.insert("app".into(), "amg2023".into());
            run.meta.insert("system".into(), "tioga".into());
            run.meta.insert("ranks".into(), ranks.to_string());
            let mut reg = AggRegion {
                is_comm_region: true,
                ..Default::default()
            };
            reg.time.push(1.0);
            let mut w = AggMetric::default();
            w.push(0.25 * ranks as f64);
            reg.mpi_wait = Some(w);
            let mut x = AggMetric::default();
            x.push(0.5);
            reg.mpi_transfer = Some(x);
            run.regions.insert("main/matvec_comm_level_0".into(), reg);
            run
        };
        let t = Thicket::new(vec![mk(8), mk(64)]);
        let dir = std::env::temp_dir().join(format!("fig8_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let txt = fig8(&t, Some(dir.as_path())).unwrap();
        assert!(txt.contains("Fig 8"), "{}", txt);
        assert!(txt.contains("wait"), "{}", txt);
        let csv = std::fs::read_to_string(dir.join("fig8_amg2023_tioga.csv")).unwrap();
        assert!(csv.starts_with("series,ranks,avg_seconds_per_rank"), "{}", csv);
        assert!(csv.contains("wait,8,"), "{}", csv);
        assert!(csv.contains("transfer,64,"), "{}", csv);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fig9_renders_critpath_shares_or_explains() {
        use crate::caliper::{AggRegion, RegionTraceStats, RunProfile};
        // no trace payloads anywhere: explanatory line
        let txt = fig9(&Thicket::new(vec![]), None).unwrap();
        assert!(txt.contains("--channels"), "{}", txt);

        let mk = |ranks: usize, halo_secs: f64| {
            let mut run = RunProfile::default();
            run.meta.insert("app".into(), "kripke".into());
            run.meta.insert("system".into(), "tioga".into());
            run.meta.insert("ranks".into(), ranks.to_string());
            let mut comm = AggRegion {
                is_comm_region: true,
                ..Default::default()
            };
            comm.time.push(1.0);
            comm.trace = Some(RegionTraceStats {
                critpath: halo_secs,
                late_sender: (2, 0.5),
                ..Default::default()
            });
            run.regions.insert("main/sweep_comm".into(), comm);
            let mut main = AggRegion::default();
            main.time.push(2.0);
            main.trace = Some(RegionTraceStats {
                critpath: 2.0 - halo_secs,
                ..Default::default()
            });
            run.regions.insert("main".into(), main);
            run
        };
        let t = Thicket::new(vec![mk(8, 0.5), mk(64, 1.5)]);
        let dir = std::env::temp_dir().join(format!("fig9_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let txt = fig9(&t, Some(dir.as_path())).unwrap();
        assert!(txt.contains("Fig 9"), "{}", txt);
        assert!(txt.contains("critical-path share"), "{}", txt);
        let csv = std::fs::read_to_string(dir.join("fig9_kripke_tioga.csv")).unwrap();
        assert!(csv.starts_with("series,ranks,critpath_fraction"), "{}", csv);
        assert!(csv.contains("main/sweep_comm,8,"), "{}", csv);
        assert!(csv.contains("main/sweep_comm,64,"), "{}", csv);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn trace_report_renders_wait_states_and_critpath() {
        use crate::trace::{RankTrace, RunTrace, TraceEvent};
        let tr = RankTrace {
            rank: 0,
            capacity: 64,
            dropped: 0,
            paths: vec!["main".into()],
            events: vec![
                TraceEvent::RegionEnter { path: 0, t: 0.0 },
                TraceEvent::Coll {
                    kind: crate::mpisim::CollKind::Barrier,
                    ctx: 0,
                    seq: 0,
                    comm_size: 2,
                    bytes: 0,
                    t_start: 0.25,
                    sync: 0.75,
                    t_end: 0.8,
                },
                TraceEvent::RegionExit { path: 0, t: 1.0 },
            ],
        };
        let peer = RankTrace {
            rank: 1,
            capacity: 64,
            dropped: 0,
            paths: vec!["main".into()],
            events: vec![
                TraceEvent::RegionEnter { path: 0, t: 0.0 },
                TraceEvent::Coll {
                    kind: crate::mpisim::CollKind::Barrier,
                    ctx: 0,
                    seq: 0,
                    comm_size: 2,
                    bytes: 0,
                    t_start: 0.75,
                    sync: 0.75,
                    t_end: 0.8,
                },
                TraceEvent::RegionExit { path: 0, t: 1.0 },
            ],
        };
        let rt = RunTrace::new(vec![tr, peer]);
        let rep = trace_report(&rt);
        assert!(rep.contains("wait-at-collective"), "{}", rep);
        assert!(rep.contains("critical path:"), "{}", rep);
        assert!(rep.contains("1.000s"), "end-to-end span: {}", rep);
        let g = trace_gantt(&rt, 40);
        assert!(g.contains("rank    0 |"), "{}", g);
        assert!(g.contains('C'), "collective wait lane: {}", g);
    }

    #[test]
    fn table4_renders_with_data() {
        use crate::caliper::{AggRegion, RunProfile};
        let mut run = RunProfile::default();
        run.meta.insert("app".into(), "kripke".into());
        run.meta.insert("system".into(), "dane".into());
        run.meta.insert("ranks".into(), "64".into());
        let mut reg = AggRegion {
            is_comm_region: true,
            max_send: 24576,
            ..Default::default()
        };
        reg.bytes_sent.push(4.0e9);
        reg.sends.push(184320.0);
        reg.time.push(1.0);
        run.regions.insert("main/sweep_comm".into(), reg);
        let t = Thicket::new(vec![run]);
        let rendered = table4(&t);
        assert!(rendered.contains("kripke (dane) - 64"));
        assert!(rendered.contains("4.00E+09"));
    }
}
