//! `coordinator` — the leader process: runs experiment campaigns, collects
//! profiles, and regenerates every table and figure of the paper.
//!
//! [`campaign`] executes the Table III matrix (each cell = one simulated
//! multi-rank job) and persists aggregated profiles; [`figures`] turns a
//! [`crate::thicket::Thicket`] of profiles into the paper's tables/figures
//! (text + CSV); [`bench`] is the `repro bench` performance suite — it
//! measures simulator cell throughput, hook-dispatch and trace-capture
//! cost, and allocations per message, writes the schema-versioned
//! `BENCH_v1.json` trajectory, and powers the CI regression gate
//! (`--check`); [`cli`] is the `repro` command-line surface.

pub mod bench;
pub mod campaign;
pub mod cli;
pub mod figures;
