//! `coordinator` — the leader process: runs experiment campaigns, collects
//! profiles, and regenerates every table and figure of the paper.
//!
//! [`campaign`] executes the Table III matrix (each cell = one simulated
//! multi-rank job) and persists aggregated profiles; [`figures`] turns a
//! [`crate::thicket::Thicket`] of profiles into the paper's tables/figures
//! (text + CSV); [`cli`] is the `repro` command-line surface.

pub mod campaign;
pub mod cli;
pub mod figures;
