//! # commscope
//!
//! A communication-pattern analysis stack for MPI-style HPC applications,
//! reproducing *"Leveraging Caliper and Benchpark to Analyze MPI
//! Communication Patterns: Insights from AMG2023, Kripke, and Laghos"*
//! (Nansamba et al., CS.DC 2025) on a fully self-contained, simulated
//! substrate.
//!
//! The stack has six cooperating layers (see `DESIGN.md` for the full
//! inventory and the paper-experiment index):
//!
//! 1. [`mpisim`] — a deterministic simulated MPI runtime: thread-per-rank,
//!    logical clocks, per-architecture network/compute models (Dane-like CPU
//!    and Tioga-like GPU machines).
//! 2. [`caliper`] — the paper's contribution: region annotations plus
//!    **communication regions** whose profiler records message, rank, and
//!    volume statistics per region instance (Table I of the paper).
//! 3. [`apps`] — faithful communication analogs of the three benchmarks:
//!    AMG2023 (multigrid, `MatVecComm`), Kripke (KBA sweep, `sweep_comm`),
//!    and Laghos (Lagrangian hydro, `halo_exchange` + dt reductions).
//! 4. [`trace`] — the event-level layer over the same hook chain: per-rank
//!    timelines, wait-state classification (late sender / late receiver /
//!    wait-at-collective), and critical-path extraction attributed to
//!    Caliper regions.
//! 5. [`benchpark`] + [`thicket`] — reproducible experiment specifications,
//!    the scaling-study runner, and multi-run exploratory analysis that
//!    regenerates every table and figure in the paper's evaluation.
//! 6. [`runtime`] — the PJRT bridge: loads the AOT-compiled JAX/Pallas
//!    compute kernels (HLO text under `artifacts/`) and executes them from
//!    the Rust hot path, proving the three-layer composition end to end.
//!
//! The experiment matrix is executed by the batched, work-stealing
//! [`coordinator::campaign::CampaignExecutor`] (cells are independent
//! simulated worlds, so campaigns parallelize with `--jobs N`). Around the
//! batch path sit two service layers: [`store`], the content-addressed
//! artifact store with deterministic profile diffing (`repro diff`), and
//! [`serve`], the campaign service daemon (`repro serve`) answering cell
//! requests over a Unix socket — see `docs/SERVICE.md`.

// CI gates on `cargo clippy -- -D warnings`. The style/complexity lints
// below are deliberate idioms of this codebase, allowed once here rather
// than sprinkled per-site:
// - too_many_arguments: the collective board plumbs full call context
//   (`CollBoard::run`).
// - new_without_default: internal plumbing types use bare `new()`
//   (mailboxes, boards, clocks).
// - type_complexity: ad-hoc tuple annotations in the runner's per-app
//   dispatch.
#![allow(
    clippy::too_many_arguments,
    clippy::new_without_default,
    clippy::type_complexity
)]

pub mod apps;
pub mod benchpark;
pub mod caliper;
pub mod coordinator;
pub mod mpisim;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod thicket;
pub mod trace;
pub mod util;
