//! # commscope
//!
//! A communication-pattern analysis stack for MPI-style HPC applications,
//! reproducing *"Leveraging Caliper and Benchpark to Analyze MPI
//! Communication Patterns: Insights from AMG2023, Kripke, and Laghos"*
//! (Nansamba et al., CS.DC 2025) on a fully self-contained, simulated
//! substrate.
//!
//! The stack has five cooperating layers (see `DESIGN.md` for the full
//! inventory and the paper-experiment index):
//!
//! 1. [`mpisim`] — a deterministic simulated MPI runtime: thread-per-rank,
//!    logical clocks, per-architecture network/compute models (Dane-like CPU
//!    and Tioga-like GPU machines).
//! 2. [`caliper`] — the paper's contribution: region annotations plus
//!    **communication regions** whose profiler records message, rank, and
//!    volume statistics per region instance (Table I of the paper).
//! 3. [`apps`] — faithful communication analogs of the three benchmarks:
//!    AMG2023 (multigrid, `MatVecComm`), Kripke (KBA sweep, `sweep_comm`),
//!    and Laghos (Lagrangian hydro, `halo_exchange` + dt reductions).
//! 4. [`benchpark`] + [`thicket`] — reproducible experiment specifications,
//!    the scaling-study runner, and multi-run exploratory analysis that
//!    regenerates every table and figure in the paper's evaluation.
//! 5. [`runtime`] — the PJRT bridge: loads the AOT-compiled JAX/Pallas
//!    compute kernels (HLO text under `artifacts/`) and executes them from
//!    the Rust hot path, proving the three-layer composition end to end.

pub mod apps;
pub mod benchpark;
pub mod caliper;
pub mod coordinator;
pub mod mpisim;
pub mod runtime;
pub mod thicket;
pub mod util;
