//! Cross-thread compute service: one dedicated thread owns the PJRT
//! [`Executor`]; simulated ranks talk to it through a cloneable
//! [`ComputeHandle`].
//!
//! The indirection exists because the `xla` crate's client types are
//! `Rc`-based (not `Send`), while our ranks are OS threads — under either
//! execution engine ([`crate::mpisim::Engine`]): the event engine also
//! keeps one OS thread per rank (as a parked coroutine stack), so the
//! handoff story is engine-independent. It also mirrors the deployment
//! reality the paper's Tioga runs have — many ranks feeding shared
//! accelerator queues. Requests are serialized per service thread; for
//! the small canonical artifact shapes this is not a bottleneck
//! (measured in EXPERIMENTS.md §Perf).

use crate::util::sync::{mpsc, Arc};

use anyhow::{anyhow, Result};

use super::executor::Executor;

enum Request {
    Execute {
        name: String,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::SyncSender<Result<Vec<Vec<f32>>, String>>,
    },
    Platform {
        reply: mpsc::SyncSender<String>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle used by rank threads.
#[derive(Clone)]
pub struct ComputeHandle {
    tx: mpsc::SyncSender<Request>,
}

impl ComputeHandle {
    /// Execute a compiled model; blocks until the service replies.
    pub fn execute(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Execute {
                name: name.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("compute service is down"))?;
        rx.recv()
            .map_err(|_| anyhow!("compute service dropped the reply"))?
            .map_err(|e| anyhow!(e))
    }

    pub fn platform(&self) -> Result<String> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Platform { reply })
            .map_err(|_| anyhow!("compute service is down"))?;
        rx.recv().map_err(|_| anyhow!("no reply"))
    }
}

/// The owning side: spawns the service thread, shuts it down on drop.
pub struct ComputeService {
    tx: mpsc::SyncSender<Request>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ComputeService {
    /// Start a service over the artifacts in `dir`. Fails fast if the
    /// artifacts are missing or won't compile.
    pub fn start(dir: impl Into<std::path::PathBuf>) -> Result<ComputeService> {
        let dir = dir.into();
        // Bounded queue: backpressure instead of unbounded memory if
        // ranks outrun the accelerator thread.
        let (tx, rx) = mpsc::sync_channel::<Request>(64);
        let (init_tx, init_rx) = mpsc::sync_channel::<Result<(), String>>(1);
        let join = std::thread::Builder::new()
            .name("pjrt-compute".to_string())
            .spawn(move || {
                let exec = match Executor::load(&dir) {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(format!("{:#}", e)));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Execute {
                            name,
                            inputs,
                            reply,
                        } => {
                            let refs: Vec<&[f32]> =
                                inputs.iter().map(|v| v.as_slice()).collect();
                            let res = exec
                                .execute_f32(&name, &refs)
                                .map_err(|e| format!("{:#}", e));
                            let _ = reply.send(res);
                        }
                        Request::Platform { reply } => {
                            let _ = reply.send(exec.platform());
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawning compute service thread");
        init_rx
            .recv()
            .map_err(|_| anyhow!("compute service died during init"))?
            .map_err(|e| anyhow!(e))?;
        Ok(ComputeService {
            tx,
            join: Some(join),
        })
    }

    pub fn handle(&self) -> ComputeHandle {
        ComputeHandle {
            tx: self.tx.clone(),
        }
    }

    /// Start and return a shared handle, or `None` (with a warning) when
    /// artifacts are absent — callers fall back to the native backend.
    pub fn try_start_shared(dir: &str) -> Option<(Arc<ComputeService>, ComputeHandle)> {
        match ComputeService::start(dir) {
            Ok(svc) => {
                let h = svc.handle();
                Some((Arc::new(svc), h))
            }
            Err(e) => {
                eprintln!("[runtime] PJRT service unavailable ({}); using native backend", e);
                None
            }
        }
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
