//! `runtime` — the PJRT bridge that executes the AOT-compiled JAX/Pallas
//! artifacts from the Rust hot path.
//!
//! Build-time Python (`python/compile/aot.py`) lowers the L2 models to HLO
//! **text** under `artifacts/` (text, not serialized proto — xla_extension
//! 0.5.1 rejects jax ≥ 0.5's 64-bit instruction ids; the text parser
//! reassigns them). At startup the Rust side:
//!
//! 1. [`artifact`] parses `artifacts/manifest.json` (names, shapes, dtypes),
//! 2. [`executor`] creates a `PjRtClient::cpu()`, loads each
//!    `<name>.hlo.txt` via `HloModuleProto::from_text_file`, compiles it
//!    once, and executes with concrete buffers,
//! 3. [`service`] wraps the executor in a dedicated compute thread (the
//!    `xla` crate's handles are `Rc`-based and thus not `Send`), exposing a
//!    cloneable, thread-safe [`service::ComputeHandle`] that simulated ranks
//!    call — the software analog of node-shared accelerators.

pub mod artifact;
pub mod executor;
pub mod service;

pub use artifact::{Manifest, ModelInfo, TensorSpec};
pub use executor::Executor;
pub use service::{ComputeHandle, ComputeService};

/// Default artifact directory, relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
