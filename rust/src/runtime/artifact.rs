//! Artifact manifest: what `python/compile/aot.py` produced.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one tensor as recorded in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("tensor spec missing shape")?
            .iter()
            .map(|v| v.as_u64().map(|u| u as usize))
            .collect::<Option<Vec<_>>>()
            .context("non-integer dim")?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .context("tensor spec missing dtype")?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-compiled model.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json is not valid JSON")?;
        let obj = match &j {
            Json::Obj(m) => m,
            _ => bail!("manifest.json root must be an object"),
        };
        let mut models = BTreeMap::new();
        for (name, entry) in obj {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .with_context(|| format!("model {} missing file", name))?
                .to_string();
            let specs = |key: &str| -> Result<Vec<TensorSpec>> {
                entry
                    .get(key)
                    .and_then(Json::as_arr)
                    .with_context(|| format!("model {} missing {}", name, key))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    file,
                    inputs: specs("inputs")?,
                    outputs: specs("outputs")?,
                },
            );
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .with_context(|| format!("model '{}' not in manifest", name))
    }

    /// Absolute path of a model's HLO text file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.model(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "amg_jacobi": {
        "file": "amg_jacobi.hlo.txt",
        "inputs": [
          {"shape": [18,18,18], "dtype": "float32"},
          {"shape": [16,16,16], "dtype": "float32"}
        ],
        "outputs": [{"shape": [16,16,16], "dtype": "float32"}]
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let model = m.model("amg_jacobi").unwrap();
        assert_eq!(model.inputs.len(), 2);
        assert_eq!(model.inputs[0].shape, vec![18, 18, 18]);
        assert_eq!(model.inputs[0].elements(), 18 * 18 * 18);
        assert_eq!(model.outputs[0].dtype, "float32");
        assert_eq!(
            m.hlo_path("amg_jacobi").unwrap(),
            PathBuf::from("/tmp/a/amg_jacobi.hlo.txt")
        );
    }

    #[test]
    fn missing_model_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn scalar_output_shape() {
        let text = r#"{"m": {"file": "m.hlo.txt", "inputs": [], "outputs": [{"shape": [], "dtype": "float32"}]}}"#;
        let m = Manifest::parse(text, PathBuf::from(".")).unwrap();
        assert_eq!(m.model("m").unwrap().outputs[0].elements(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("[1,2]", PathBuf::from(".")).is_err());
        assert!(Manifest::parse("{\"x\": {}}", PathBuf::from(".")).is_err());
    }
}
