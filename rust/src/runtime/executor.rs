//! Single-thread PJRT executor: load HLO text, compile once, execute many.
//!
//! Not `Send` (the `xla` crate's client is `Rc`-based); see
//! [`super::service`] for the cross-thread front end.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifact::{Manifest, ModelInfo};

/// Owns the PJRT CPU client and the compiled executables.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    /// Create a CPU PJRT client and eagerly compile every model in the
    /// manifest (compile-once, execute-many).
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Executor> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = HashMap::new();
        for name in manifest.models.keys() {
            let path = manifest.hlo_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", name))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Executor {
            client,
            manifest,
            exes,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.manifest.model(name)
    }

    /// Execute `name` with f32 inputs (row-major, shapes per the manifest).
    /// Returns one flat f32 vector per output (scalars → length-1).
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let info = self.manifest.model(name)?;
        if inputs.len() != info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                name,
                info.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, spec)) in inputs.iter().zip(&info.inputs).enumerate() {
            if data.len() != spec.elements() {
                bail!(
                    "{}: input {} has {} elements, manifest says {:?} = {}",
                    name,
                    i,
                    data.len(),
                    spec.shape,
                    spec.elements()
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data);
            let lit = if dims.is_empty() {
                lit
            } else {
                lit.reshape(&dims)
                    .with_context(|| format!("reshaping input {} of {}", i, name))?
            };
            literals.push(lit);
        }
        let exe = self
            .exes
            .get(name)
            .with_context(|| format!("executable '{}' not loaded", name))?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", name))?;
        // return_tuple=True at lowering: one tuple literal on device 0.
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple.to_tuple().context("untupling result")?;
        if parts.len() != info.outputs.len() {
            bail!(
                "{}: manifest declares {} outputs, executable returned {}",
                name,
                info.outputs.len(),
                parts.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (i, part) in parts.into_iter().enumerate() {
            let v = part
                .to_vec::<f32>()
                .with_context(|| format!("decoding output {} of {}", i, name))?;
            if v.len() != info.outputs[i].elements() {
                bail!(
                    "{}: output {} has {} elements, manifest says {}",
                    name,
                    i,
                    v.len(),
                    info.outputs[i].elements()
                );
            }
            outs.push(v);
        }
        Ok(outs)
    }
}

// Tests that need real artifacts live in rust/tests/runtime_roundtrip.rs
// (they require `make artifacts` to have run).
