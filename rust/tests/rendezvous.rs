//! Integration tests for the rendezvous-capable request engine: protocol
//! crossover pricing, deadlock-freedom of symmetric large-message
//! exchanges posted as isend/irecv/waitall, receiver-post-gated completion,
//! and the `mpi-time` channel's Waitall wait-vs-transfer attribution up
//! through the AMG halo cell and the fig8 figure.

use std::collections::BTreeMap;
use std::time::Duration;

use commscope::apps::amg::{run_amg, AmgConfig, CoarseStrategy};
use commscope::apps::common::ComputeBackend;
use commscope::caliper::aggregate::{aggregate, check_conservation};
use commscope::caliper::ChannelConfig;
use commscope::coordinator::figures;
use commscope::mpisim::{MachineModel, MpiError, Request, World, WorldConfig};
use commscope::thicket::Thicket;

/// Test machine with a small eager threshold so modest payloads exercise
/// the rendezvous path.
fn small_threshold_machine(threshold: usize) -> MachineModel {
    let mut m = MachineModel::test_machine();
    m.net.eager_threshold = threshold;
    m
}

fn cfg(n: usize, m: MachineModel) -> WorldConfig {
    WorldConfig::new(n, m).with_timeout(Duration::from_secs(20))
}

/// Crossing the eager threshold costs exactly the rendezvous handshake
/// plus the marginal byte cost — a bounded, physical protocol step, not a
/// pathological discontinuity.
#[test]
fn cost_continuity_at_eager_threshold() {
    let m = small_threshold_machine(1 << 13);
    let thr = m.net.eager_threshold;
    let completion = |bytes: usize| {
        let mach = m.clone();
        World::run(cfg(2, mach), move |rank| {
            let world = rank.world();
            if rank.rank == 0 {
                let req = rank.isend(&vec![0u8; bytes], 1, 0, &world).unwrap();
                rank.wait_send(req).unwrap();
            } else {
                let _ = rank.recv::<u8>(Some(0), 0, &world).unwrap();
            }
            rank.now()
        })[1]
    };
    let below = completion(thr - 1);
    let at = completion(thr);
    let above = completion(thr + 1);
    // below the threshold: pure Hockney marginal cost per byte
    assert!(
        (at - below - m.net.beta_intra).abs() < 1e-15,
        "eager side must be smooth: {} vs {}",
        at,
        below
    );
    // the crossover jump is exactly the handshake + 1 byte of wire time
    let jump = above - at;
    let expect = m.handshake_time(0, 1) + m.net.beta_intra;
    assert!(
        (jump - expect).abs() < 1e-12,
        "crossover jump {} must equal handshake+β {}",
        jump,
        expect
    );
    // and it is a strict (but bounded) increase
    assert!(above > at && jump < 1e-5, "jump {}", jump);
}

/// Two ranks exchanging above-threshold messages with isend/irecv/waitall
/// must complete without deadlock, round-trip the payloads, and produce a
/// virtual time that does not depend on the request order in waitall.
#[test]
fn symmetric_large_exchange_is_deadlock_free_and_order_invariant() {
    let elems = 64 * 1024; // 512 KiB of f64 ≫ threshold
    let run = |recv_first: bool| {
        let m = small_threshold_machine(4096);
        World::run(cfg(2, m), move |rank| {
            let world = rank.world();
            let peer = 1 - rank.rank;
            let mine: Vec<f64> = vec![rank.rank as f64 + 1.0; elems];
            let mut reqs: Vec<Request> = Vec::new();
            if recv_first {
                reqs.push(rank.irecv(Some(peer), 5, &world).unwrap().into());
                reqs.push(rank.isend(&mine, peer, 5, &world).unwrap().into());
            } else {
                reqs.push(rank.isend(&mine, peer, 5, &world).unwrap().into());
                reqs.push(rank.irecv(Some(peer), 5, &world).unwrap().into());
            }
            let done = rank.waitall::<f64>(reqs).unwrap();
            let got: Vec<f64> = done.into_iter().flatten().flat_map(|(d, _st)| d).collect();
            assert_eq!(got.len(), elems);
            assert!(got.iter().all(|v| *v == peer as f64 + 1.0));
            rank.now()
        })
    };
    let a = run(true);
    let b = run(false);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "waitall must be request-order invariant: {:?} vs {:?}",
            a,
            b
        );
    }
}

/// Two ranks BLOCKING-sending large messages to each other is a genuine
/// deadlock in real MPI (both sides stuck in the rendezvous handshake);
/// the engine's guard must surface it as `SendTimeout`, not hang.
#[test]
fn symmetric_blocking_rendezvous_sends_deadlock_with_context() {
    let m = small_threshold_machine(1024);
    let errs = World::run(
        WorldConfig::new(2, m).with_timeout(Duration::from_millis(300)),
        |rank| {
            let world = rank.world();
            let peer = 1 - rank.rank;
            rank.send(&vec![0u8; 1 << 16], peer, 9, &world).unwrap_err()
        },
    );
    for (r, e) in errs.iter().enumerate() {
        match e {
            MpiError::SendTimeout { rank, dst, millis, .. } => {
                assert_eq!(*rank, r);
                assert_eq!(*dst, 1 - r);
                assert_eq!(*millis, 300);
            }
            other => panic!("expected SendTimeout, got {:?}", other),
        }
        assert!(e.to_string().contains("rendezvous"), "{}", e);
    }
}

/// An above-threshold message's completion must move with the receiver's
/// post time (`max(sender_ready, receiver_post) + handshake + wire`),
/// while a below-threshold message's arrival must not.
#[test]
fn rendezvous_completion_tracks_receiver_post_eager_does_not() {
    let m = small_threshold_machine(1024);
    let finish = |bytes: usize, delay: f64| {
        let mach = m.clone();
        World::run(cfg(2, mach), move |rank| {
            let world = rank.world();
            if rank.rank == 0 {
                let req = rank.isend(&vec![0u8; bytes], 1, 0, &world).unwrap();
                rank.wait_send(req).unwrap();
            } else {
                rank.advance(delay);
                let _ = rank.recv::<u8>(Some(0), 0, &world).unwrap();
            }
            rank.now()
        })[1]
    };
    // rendezvous: delaying the post by 1s delays completion by exactly 1s
    let big = 8192;
    let on_time = finish(big, 0.0);
    let late = finish(big, 1.0);
    assert!(
        ((late - on_time) - 1.0).abs() < 1e-9,
        "rendezvous completion must track the post: {} -> {}",
        on_time,
        late
    );
    // eager: the message was buffered; a 1s-late post costs ~1s total, not
    // 1s + transfer (completion floors at the post time + recv overhead)
    let small = 256;
    let e_on_time = finish(small, 0.0);
    let e_late = finish(small, 1.0);
    assert!(
        e_late - 1.0 < e_on_time,
        "eager arrival must not re-pay the transfer after a late post: {} vs {}",
        e_late,
        e_on_time
    );
}

/// The acceptance cell: an AMG run whose level-0 halos cross the eager
/// threshold reports nonzero Waitall wait time on `matvec_comm_level_0`
/// through the `mpi-time` channel, and fig8 renders the wait-breakdown
/// CSV from exactly that profile.
#[test]
fn amg_halo_reports_waitall_wait_time_and_fig8_renders() {
    // 8×8×8 zones/rank ⇒ 512-byte faces; threshold 256 ⇒ rendezvous halos.
    let amg = AmgConfig {
        pdims: [2, 2, 2],
        local: [8, 8, 8],
        niter: 3,
        exchanges_per_level: 3,
        strategy: CoarseStrategy::CpuNaive,
        backend: ComputeBackend::Native,
        seed: 7,
        channels: ChannelConfig::parse("comm-stats,mpi-time").unwrap(),
    };
    let world = WorldConfig::new(8, small_threshold_machine(256));
    let res = run_amg(world, &amg);
    check_conservation(&res.profiles).unwrap();
    let mut meta = BTreeMap::new();
    meta.insert("app".to_string(), "amg2023".to_string());
    meta.insert("system".to_string(), "testbox".to_string());
    meta.insert("ranks".to_string(), "8".to_string());
    let run = aggregate(meta, &res.profiles);

    let halo = run.region("matvec_comm_level_0").unwrap().1;
    let wait = halo.mpi_wait.as_ref().expect("mpi-time split recorded");
    assert!(
        wait.total() > 0.0,
        "rendezvous halos must report Waitall wait time"
    );
    let transfer = halo.mpi_transfer.as_ref().unwrap();
    assert!(transfer.total() > 0.0);
    let total = halo.mpi_time.as_ref().unwrap();
    assert!(
        wait.total() + transfer.total() <= total.total() + 1e-9,
        "split cannot exceed total MPI time"
    );

    // fig8 renders the breakdown CSV from this profile
    let dir = std::env::temp_dir().join(format!("rdvfig8_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let t = Thicket::new(vec![run]);
    let txt = figures::fig8(&t, Some(dir.as_path())).unwrap();
    assert!(txt.contains("Fig 8"), "{}", txt);
    let csv = std::fs::read_to_string(dir.join("fig8_amg2023_testbox.csv")).unwrap();
    let wait_rows: Vec<&str> = csv.lines().filter(|l| l.starts_with("wait,")).collect();
    assert!(!wait_rows.is_empty(), "{}", csv);
    assert!(
        wait_rows.iter().any(|l| {
            l.rsplit(',')
                .next()
                .and_then(|v| v.parse::<f64>().ok())
                .map(|v| v > 0.0)
                .unwrap_or(false)
        }),
        "fig8 wait series must carry the nonzero wait: {}",
        csv
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Below the threshold nothing changes: the same AMG cell on the stock
/// test machine (8 KiB eager limit, 512-byte faces) reports zero wait —
/// eager semantics are preserved end to end.
#[test]
fn below_threshold_amg_reports_no_rendezvous_wait() {
    let amg = AmgConfig {
        pdims: [2, 2, 2],
        local: [8, 8, 8],
        niter: 2,
        exchanges_per_level: 3,
        strategy: CoarseStrategy::CpuNaive,
        backend: ComputeBackend::Native,
        seed: 7,
        channels: ChannelConfig::parse("comm-stats,mpi-time").unwrap(),
    };
    let world = WorldConfig::new(8, MachineModel::test_machine());
    let res = run_amg(world, &amg);
    let run = aggregate(BTreeMap::new(), &res.profiles);
    let halo = run.region("matvec_comm_level_0").unwrap().1;
    // The split exists (channel on), but eager halos never pay the
    // handshake; wait can only stem from compute skew between neighbors,
    // which this symmetric 2×2×2 box does not produce at level 0... it
    // can, however, inherit skew from the coarse gather, so only assert
    // the rendezvous-specific invariant: wait ≪ transfer.
    if let (Some(w), Some(t)) = (halo.mpi_wait.as_ref(), halo.mpi_transfer.as_ref()) {
        assert!(
            w.total() <= t.total(),
            "eager halo wait {} should not dominate transfer {}",
            w.total(),
            t.total()
        );
    }
}

/// waitany + test complete a mixed request set above the threshold.
#[test]
fn waitany_and_test_on_mixed_requests() {
    let m = small_threshold_machine(512);
    let res = World::run(cfg(2, m), |rank| {
        let world = rank.world();
        if rank.rank == 0 {
            // large send: pending until rank 1 posts
            let sreq = rank.isend(&vec![7u8; 4096], 1, 1, &world).unwrap();
            let mut reqs: Vec<Request> = vec![sreq.into()];
            let (idx, none) = rank.waitany::<u8>(&mut reqs).unwrap();
            assert_eq!(idx, 0);
            assert!(none.is_none(), "send slots carry no payload");
            rank.now()
        } else {
            let req = rank.irecv(Some(0), 1, &world).unwrap();
            let r: Request = req.into();
            // test() flips to true once the envelope is deposited; wait
            // for it without consuming, then complete via waitall.
            let deadline = std::time::Instant::now() + Duration::from_secs(10);
            while !rank.test(&r) {
                assert!(std::time::Instant::now() < deadline, "never deposited");
                std::thread::yield_now();
            }
            let done = rank.waitall::<u8>(vec![r]).unwrap();
            let (data, st) = done.into_iter().next().unwrap().unwrap();
            assert_eq!(st.bytes, 4096);
            assert!(data.iter().all(|b| *b == 7));
            rank.now()
        }
    });
    assert!(res.iter().all(|t| *t > 0.0));
}
