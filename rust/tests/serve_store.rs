//! Contract of the service tier (PR: `repro serve` / `repro diff`):
//!
//! - the artifact store round-trips cells byte-identically to the batch
//!   campaign layout, and its staleness rules reuse the same stamping;
//! - concurrent submits of one cell compute exactly once (single-flight);
//! - the daemon answers submit → progress → result over a real Unix
//!   socket, with the second submit observably served from the store;
//! - the diff engine reports an empty self-diff, flags significant
//!   deltas across fidelities, and renders byte-stably across runs and
//!   engines;
//! - `repro diff` exits with the verdict code (0/3/4) so CI can gate.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use commscope::benchpark::runner::RunOptions;
use commscope::benchpark::{run_cell_full, AppKind, ExperimentSpec, Scaling, SystemId};
use commscope::coordinator::bench::{render_bench_file, BenchEntry};
use commscope::coordinator::campaign::{run_campaign_report, selected_cells, CampaignOptions};
use commscope::coordinator::cli::dispatch;
use commscope::serve::protocol::{Client, Request};
use commscope::serve::{serve, ServeOptions};
use commscope::store::diff::{DiffVerdict, ProfileDiff};
use commscope::store::{profile_path, ArtifactStore, StoreOutcome};
use commscope::util::cli::Args;
use commscope::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("{}_{}", name, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Fast full-fidelity-shaped options (same shrink factors as the other
/// integration suites use to keep cells sub-second).
fn fast() -> RunOptions {
    RunOptions {
        iter_shrink: 10,
        size_shrink: 8,
        ..Default::default()
    }
}

fn amg8() -> ExperimentSpec {
    ExperimentSpec {
        app: AppKind::Amg2023,
        system: SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks: 8,
    }
}

fn args(line: &str) -> Args {
    Args::parse(line.split_whitespace().map(|s| s.to_string()))
}

// ---------------------------------------------------------------------------
// Store
// ---------------------------------------------------------------------------

#[test]
fn store_artifacts_are_byte_identical_to_the_batch_campaign() {
    let batch_dir = tmp("ss_batch");
    let store_dir = tmp("ss_store");

    // Batch side: the ≤8-rank matrix through `repro campaign`'s writer.
    let mut opts = CampaignOptions::new(&batch_dir);
    opts.run = fast();
    opts.max_ranks = Some(8);
    let (thicket, report) = run_campaign_report(&opts, false).unwrap();
    assert!(report.failures.is_empty());
    assert!(!thicket.is_empty());

    // Store side: the same cells through the daemon's store.
    let store = ArtifactStore::open(&store_dir).unwrap();
    let run = fast();
    for spec in selected_cells(&opts) {
        let (_, outcome) = store
            .get_or_compute(&spec, &run, false, || run_cell_full(&spec, &run))
            .unwrap();
        assert_eq!(outcome, StoreOutcome::Miss, "{}", spec.id());
        let batch_bytes = std::fs::read(profile_path(&batch_dir, &spec.id())).unwrap();
        let store_bytes = std::fs::read(profile_path(&store_dir, &spec.id())).unwrap();
        assert_eq!(batch_bytes, store_bytes, "{} artifact diverged", spec.id());
        // Second request: served from the store, not recomputed.
        let (_, again) = store
            .get_or_compute(&spec, &run, false, || panic!("must not recompute"))
            .unwrap();
        assert_eq!(again, StoreOutcome::Hit);
    }
    let stats = store.stats();
    assert!(stats.hits >= 3 && stats.puts >= 3, "{:?}", stats);

    let _ = std::fs::remove_dir_all(&batch_dir);
    let _ = std::fs::remove_dir_all(&store_dir);
}

#[test]
fn store_staleness_tracks_fidelity_and_channel_stamps() {
    let dir = tmp("ss_stale");
    let store = ArtifactStore::open(&dir).unwrap();
    let spec = amg8();
    let run = fast();
    let out = run_cell_full(&spec, &run).unwrap();
    store.put(&spec, &run, &out).unwrap();

    assert!(store.lookup(&spec, &run).is_some(), "same options must hit");
    // Different fidelity: the stamped iter/size shrinks no longer match.
    assert!(store.lookup(&spec, &RunOptions::smoke()).is_none());
    // Different channel spec: stale even at the same fidelity.
    let mut wider = run;
    wider.channels =
        commscope::caliper::ChannelConfig::parse("region-times,comm-stats,comm-matrix").unwrap();
    assert!(store.lookup(&spec, &wider).is_none());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn single_flight_computes_a_contested_cell_exactly_once() {
    let dir = tmp("ss_flight");
    let store = ArtifactStore::open(&dir).unwrap();
    let spec = amg8();
    let run = fast();
    let computes = AtomicUsize::new(0);
    let (hits, misses) = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..4 {
            handles.push(scope.spawn(|| {
                let (_, outcome) = store
                    .get_or_compute(&spec, &run, false, || {
                        computes.fetch_add(1, Ordering::SeqCst);
                        run_cell_full(&spec, &run)
                    })
                    .unwrap();
                outcome
            }));
        }
        let mut hits = 0;
        let mut misses = 0;
        for h in handles {
            match h.join().unwrap() {
                StoreOutcome::Hit => hits += 1,
                StoreOutcome::Miss => misses += 1,
            }
        }
        (hits, misses)
    });
    assert_eq!(computes.load(Ordering::SeqCst), 1, "leader computes once");
    assert_eq!(misses, 1);
    assert_eq!(hits, 3);

    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Daemon
// ---------------------------------------------------------------------------

#[test]
fn daemon_serves_submits_over_a_unix_socket_with_observable_cache() {
    let dir = tmp("ss_daemon");
    let socket = dir.join("repro.sock");
    let opts = ServeOptions {
        socket: socket.clone(),
        out_dir: dir.clone(),
        jobs: 2,
        run: fast(),
        verbose: false,
    };
    let daemon = std::thread::spawn(move || serve(&opts).unwrap());
    let mut client = Client::connect_retry(&socket, Duration::from_secs(30)).unwrap();

    let submit = Request::Submit {
        app: "amg2023".into(),
        system: "tioga".into(),
        ranks: 8,
        force: false,
    };
    // First submit: accepted → progress → result, computed fresh.
    let mut stages = Vec::new();
    let result = client
        .roundtrip(&submit, |ev| {
            stages.push(
                ev.get("event").and_then(Json::as_str).unwrap_or("?").to_string(),
            );
        })
        .unwrap();
    assert_eq!(result.get("event").and_then(Json::as_str), Some("result"));
    assert_eq!(result.get("cell").and_then(Json::as_str), Some("amg2023_tioga_8"));
    assert_eq!(result.get("cache").and_then(Json::as_str), Some("miss"));
    assert!(stages.contains(&"accepted".to_string()), "{:?}", stages);
    assert!(stages.contains(&"progress".to_string()), "{:?}", stages);
    assert!(profile_path(&dir, "amg2023_tioga_8").is_file());

    // Second submit: the observable store hit.
    let result = client.roundtrip(&submit, |_| {}).unwrap();
    assert_eq!(result.get("cache").and_then(Json::as_str), Some("hit"));

    let status = client.roundtrip(&Request::Status, |_| {}).unwrap();
    assert_eq!(status.get("submits").and_then(Json::as_u64), Some(2));
    assert_eq!(status.get("served_hits").and_then(Json::as_u64), Some(1));
    assert_eq!(status.get("computed").and_then(Json::as_u64), Some(1));

    // The stored artifact comes back over the wire...
    let profile = client
        .roundtrip(&Request::Result { cell: "amg2023_tioga_8".into() }, |_| {})
        .unwrap();
    assert_eq!(profile.get("event").and_then(Json::as_str), Some("profile"));
    assert!(profile.get("profile").is_some());
    // ...and a bad cell id is an error event, not a dead connection.
    let missing = client
        .roundtrip(&Request::Result { cell: "nope_tioga_8".into() }, |_| {})
        .unwrap();
    assert_eq!(missing.get("event").and_then(Json::as_str), Some("error"));

    // Self-diff through the daemon: no change, exit code 0.
    let diff = client
        .roundtrip(
            &Request::Diff {
                cell_a: "amg2023_tioga_8".into(),
                cell_b: "amg2023_tioga_8".into(),
            },
            |_| {},
        )
        .unwrap();
    assert_eq!(diff.get("verdict").and_then(Json::as_str), Some("no-change"));
    assert_eq!(diff.get("exit_code").and_then(Json::as_u64), Some(0));

    let bye = client.roundtrip(&Request::Shutdown, |_| {}).unwrap();
    assert_eq!(bye.get("event").and_then(Json::as_str), Some("ok"));
    let stats = daemon.join().unwrap();
    assert_eq!(stats.submits, 2);
    assert_eq!(stats.served_hits, 1);
    assert_eq!(stats.computed, 1);
    assert!(!socket.exists(), "socket file removed on shutdown");

    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Diff engine
// ---------------------------------------------------------------------------

#[test]
fn self_diff_is_empty_and_fidelity_diff_is_significant() {
    let spec = amg8();
    let full = run_cell_full(&spec, &fast()).unwrap().profile;
    let full_again = run_cell_full(&spec, &fast()).unwrap().profile;
    let shrunk = run_cell_full(&spec, &RunOptions::smoke()).unwrap().profile;

    // Determinism end to end: the self-diff is empty.
    let same = ProfileDiff::compute(&full, &full_again, "a", "b");
    assert_eq!(same.verdict(), DiffVerdict::NoChange);
    assert_eq!(same.significant_count(), 0);
    assert!(same.meta_changes.is_empty());

    // Shrunk fidelity: stamped meta differs and real deltas are flagged.
    let diff = ProfileDiff::compute(&full, &shrunk, "full", "smoke");
    assert!(diff.meta_changes.iter().any(|(k, _, _)| k == "iter_shrink"));
    assert!(diff.significant_count() > 0, "{}", diff.render_text());
    assert_ne!(diff.verdict(), DiffVerdict::NoChange);
    let report = diff.render_text();
    assert!(report.contains("verdict:"), "{}", report);
}

#[test]
fn diff_reports_are_byte_stable_across_runs_and_engines() {
    let spec = amg8();
    let threaded = fast();
    let event = RunOptions {
        engine: commscope::mpisim::Engine::event(),
        ..fast()
    };
    let a = run_cell_full(&spec, &threaded).unwrap().profile;
    let b = run_cell_full(&spec, &RunOptions::smoke()).unwrap().profile;
    let a_event = run_cell_full(&spec, &event).unwrap().profile;

    let text_1 = ProfileDiff::compute(&a, &b, "full", "smoke").render_text();
    let text_2 = ProfileDiff::compute(&a, &b, "full", "smoke").render_text();
    assert_eq!(text_1, text_2, "same inputs, same bytes");
    // Engine equivalence carries through the diff: swapping the threaded
    // profile for the event-engine one changes nothing.
    let text_3 = ProfileDiff::compute(&a_event, &b, "full", "smoke").render_text();
    assert_eq!(text_1, text_3, "engines must not leak into reports");
    let csv_1 = ProfileDiff::compute(&a, &b, "full", "smoke").render_csv();
    let csv_2 = ProfileDiff::compute(&a_event, &b, "full", "smoke").render_csv();
    assert_eq!(csv_1, csv_2);
    assert!(csv_1.starts_with("cell,region,channel,metric,"), "{}", csv_1);
}

// ---------------------------------------------------------------------------
// CLI exit codes
// ---------------------------------------------------------------------------

#[test]
fn repro_diff_exit_codes_follow_the_verdict_contract() {
    let dir = tmp("ss_cli");
    let spec = amg8();
    let full = run_cell_full(&spec, &fast()).unwrap().profile;
    let shrunk = run_cell_full(&spec, &RunOptions::smoke()).unwrap().profile;
    let a = dir.join("a.json");
    let b = dir.join("b.json");
    std::fs::write(&a, full.to_json().to_string_pretty()).unwrap();
    std::fs::write(&b, shrunk.to_json().to_string_pretty()).unwrap();

    // Self-diff: exit 0.
    assert_eq!(dispatch(&args(&format!("diff {} {}", a.display(), a.display()))), 0);
    // Cross-fidelity: improved (3) or regressed (4), never silent.
    let code = dispatch(&args(&format!("diff {} {}", a.display(), b.display())));
    assert!(code == 3 || code == 4, "got {}", code);
    // Campaign-directory form: a dir with profiles/ diffed against itself.
    let camp = dir.join("camp");
    std::fs::create_dir_all(camp.join("profiles")).unwrap();
    std::fs::write(camp.join("profiles").join(format!("{}.json", spec.id())),
        full.to_json().to_string_pretty()).unwrap();
    assert_eq!(dispatch(&args(&format!("diff {} {}", camp.display(), camp.display()))), 0);
    // Usage / IO errors stay on the generic failure code 1.
    assert_eq!(dispatch(&args("diff")), 1);
    assert_eq!(dispatch(&args("diff /nonexistent/x /nonexistent/y")), 1);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn repro_diff_bench_mode_gates_on_the_welch_verdict() {
    let dir = tmp("ss_bench_diff");
    let entry = |label: &str, mean: f64, m2: f64| BenchEntry {
        label: label.to_string(),
        smoke_cells_per_s_median: mean,
        smoke_cells_per_s_p90: mean * 1.2,
        smoke_cells: 6,
        smoke_reps: 2,
        events_per_s: 1e7,
        ns_per_hook_dispatch: 25.0,
        allocs_per_message: 4.0,
        event_ranks_per_s: 900.0,
        smoke_samples: 12,
        smoke_cells_per_s_mean: mean,
        smoke_cells_per_s_m2: m2,
        gate_verdict: String::new(),
    };
    // A clear halving with tight variance: regressed, exit 4.
    let path = dir.join("bench_regressed.json");
    std::fs::write(&path, render_bench_file(&[entry("base", 10.0, 0.11), entry("pr", 5.0, 0.11)]))
        .unwrap();
    assert_eq!(dispatch(&args(&format!("diff --bench {}", path.display()))), 4);
    // The same drop inside huge variance: statistically nothing, exit 0.
    let path = dir.join("bench_noise.json");
    std::fs::write(&path, render_bench_file(&[entry("base", 10.0, 1100.0), entry("pr", 8.0, 1100.0)]))
        .unwrap();
    assert_eq!(dispatch(&args(&format!("diff --bench {}", path.display()))), 0);
    // One entry: nothing to compare, exit 0.
    let path = dir.join("bench_single.json");
    std::fs::write(&path, render_bench_file(&[entry("base", 10.0, 0.11)])).unwrap();
    assert_eq!(dispatch(&args(&format!("diff --bench {}", path.display()))), 0);

    let _ = std::fs::remove_dir_all(&dir);
}
