//! Engine-equivalence gate: the discrete-event scheduler must be an
//! indistinguishable drop-in for the thread-per-rank engine.
//!
//! The migration contract (docs/ARCHITECTURE.md, "Execution engines") is
//! byte-identity of every artifact: same profile JSON, same trace JSONL,
//! across `Engine::Threaded`, `Engine::Event { workers: 1 }`, and
//! multi-worker event runs. Virtual timestamps are schedule-independent
//! by construction, so any divergence here is an engine bug, not noise —
//! which is what lets the event engine carry 4k+-rank campaigns that the
//! threaded engine cannot, while the threaded engine stays on as the
//! oracle at small scale.

use commscope::benchpark::experiment::{ExperimentSpec, Scaling};
use commscope::benchpark::runner::{run_cell, run_cell_full, RunOptions};
use commscope::benchpark::{AppKind, SystemId};
use commscope::caliper::ChannelConfig;
use commscope::coordinator::bench::smoke_cells;
use commscope::mpisim::{Engine, MachineModel, Rank, ReduceOp, World, WorldConfig};
use commscope::trace::write_jsonl;

fn with_engine(base: &RunOptions, engine: Engine) -> RunOptions {
    RunOptions { engine, ..*base }
}

/// Every ≤16-rank cell of the full matrix (all four apps — including
/// zmodel's dense alltoallv, the pattern most unlike the halo apps) must
/// produce the same profile bytes on both engines.
#[test]
fn smoke_matrix_profiles_byte_identical_across_engines() {
    let base = RunOptions {
        iter_shrink: 10,
        size_shrink: 8,
        ..Default::default()
    };
    let cells = smoke_cells();
    for app in [
        AppKind::Amg2023,
        AppKind::Kripke,
        AppKind::Laghos,
        AppKind::Zmodel,
    ] {
        assert!(
            cells.iter().any(|c| c.app == app),
            "{:?} missing from the smoke matrix",
            app
        );
    }
    for spec in &cells {
        let threaded = run_cell(spec, &base).unwrap();
        let event = run_cell(spec, &with_engine(&base, Engine::event())).unwrap();
        assert_eq!(
            threaded.to_json().to_string_pretty(),
            event.to_json().to_string_pretty(),
            "profile bytes diverge across engines for cell {}",
            spec.id()
        );
    }
}

/// Full-fidelity AMG on tioga keeps large halo exchanges above the eager
/// threshold, so this cell exercises the rendezvous park/wake path end to
/// end. Both the profile and the event-level trace artifact must match
/// byte for byte.
#[test]
fn rendezvous_cell_trace_bytes_identical_across_engines() {
    let spec = ExperimentSpec {
        app: AppKind::Amg2023,
        system: SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks: 8,
    };
    let base = RunOptions {
        iter_shrink: 1,
        size_shrink: 1,
        channels: ChannelConfig::parse("comm-stats,mpi-time,trace").unwrap(),
        ..Default::default()
    };
    let threaded = run_cell_full(&spec, &base).unwrap();
    let event = run_cell_full(&spec, &with_engine(&base, Engine::event())).unwrap();
    assert_eq!(
        threaded.profile.to_json().to_string_pretty(),
        event.profile.to_json().to_string_pretty(),
        "rendezvous profile diverges across engines"
    );
    let t_trace = threaded.trace.as_ref().expect("threaded trace artifact");
    let e_trace = event.trace.as_ref().expect("event trace artifact");
    assert_eq!(
        write_jsonl(t_trace),
        write_jsonl(e_trace),
        "trace JSONL diverges across engines"
    );
}

/// Worker count is wall-clock parallelism only: an `event:4` run must
/// produce the same bytes as `event:1` (and therefore as threaded).
#[test]
fn multi_worker_event_run_matches_single_worker() {
    let spec = ExperimentSpec {
        app: AppKind::Kripke,
        system: SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks: 16,
    };
    let base = RunOptions {
        iter_shrink: 10,
        size_shrink: 8,
        channels: ChannelConfig::parse("comm-stats,trace").unwrap(),
        ..Default::default()
    };
    let one = run_cell_full(&spec, &with_engine(&base, Engine::event())).unwrap();
    let four =
        run_cell_full(&spec, &with_engine(&base, Engine::parse("event:4").unwrap())).unwrap();
    assert_eq!(
        one.profile.to_json().to_string_pretty(),
        four.profile.to_json().to_string_pretty()
    );
    assert_eq!(
        write_jsonl(one.trace.as_ref().unwrap()),
        write_jsonl(four.trace.as_ref().unwrap())
    );
}

/// The payoff case: a 4096-rank world — far past where thread-per-rank
/// scheduling is usable for real campaigns — runs a ring exchange plus an
/// allreduce on the event engine and produces the exact deterministic
/// reduction.
#[test]
fn event_engine_runs_4096_rank_world() {
    const N: usize = 4096;
    let cfg = WorldConfig::new(N, MachineModel::test_machine()).with_engine(Engine::event());
    let out = World::run(cfg, |rank: &mut Rank<'_>| {
        let world = rank.world();
        let right = (rank.rank + 1) % N;
        let left = (rank.rank + N - 1) % N;
        rank.send(&[rank.rank as f64], right, 0, &world).unwrap();
        let (d, _) = rank.recv::<f64>(Some(left), 0, &world).unwrap();
        let s = rank.allreduce_f64(&[d[0]], ReduceOp::Sum, &world).unwrap();
        s[0]
    });
    let expected = (N * (N - 1) / 2) as f64;
    assert_eq!(out.len(), N);
    for s in out {
        assert_eq!(s, expected);
    }
}

/// The acceptance cell: a 4096-rank AMG2023/tioga campaign cell completes
/// on the event engine with both artifacts. CI runs this through
/// `repro campaign --engine event --extend-ranks 4096`; this test is the
/// same cell as a one-shot for local runs (`cargo test -- --ignored`).
#[test]
#[ignore = "multi-minute: 4096-rank AMG cell"]
fn amg_4096_rank_cell_completes_on_event_engine() {
    let spec = ExperimentSpec {
        app: AppKind::Amg2023,
        system: SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks: 4096,
    };
    let opts = RunOptions {
        engine: Engine::event(),
        channels: ChannelConfig::parse("comm-stats,mpi-time,trace").unwrap(),
        ..RunOptions::smoke()
    };
    let out = run_cell_full(&spec, &opts).unwrap();
    assert_eq!(out.profile.meta_usize("ranks"), Some(4096));
    let trace = out.trace.expect("trace artifact for the acceptance cell");
    assert!(!write_jsonl(&trace).is_empty());
}
