//! Integration: campaign → profiles on disk → thicket reload → every
//! figure/table renderer produces sane output with CSV side effects.

use commscope::benchpark::runner::RunOptions;
use commscope::benchpark::{AppKind, SystemId};
use commscope::coordinator::campaign::{run_campaign, selected_cells, CampaignOptions};
use commscope::coordinator::figures;
use commscope::thicket::stats;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("figtest_{}_{}", tag, std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn full_pipeline_small_scale() {
    let dir = tmpdir("pipeline");
    let mut opts = CampaignOptions::new(&dir);
    opts.run = RunOptions {
        iter_shrink: 10,
        size_shrink: 8,
        ..Default::default()
    };
    opts.max_ranks = Some(16);
    opts.verbose = false;
    // Expect the ≤16-rank cells: amg/kripke/zmodel tioga 8,16 (laghos
    // min scale is 112 → filtered out; dane min scale is 64).
    let cells = selected_cells(&opts);
    assert_eq!(cells.len(), 6, "{:?}", cells.iter().map(|c| c.id()).collect::<Vec<_>>());
    let t = run_campaign(&opts, true).unwrap();
    assert_eq!(t.len(), 6);

    // table4 renders a row per run
    let t4 = figures::table4(&t);
    assert!(t4.contains("kripke (tioga) - 8"));
    assert!(t4.contains("amg2023 (tioga) - 16"));

    // figures render and write CSVs
    let fig_dir = dir.as_path();
    let f1 = figures::fig1(&t, Some(fig_dir)).unwrap();
    assert!(f1.contains("Kripke"));
    assert!(fig_dir.join("fig1_kripke_tioga.csv").exists());
    let f2 = figures::fig2(&t, Some(fig_dir)).unwrap();
    assert!(f2.contains("MG level"));
    assert!(fig_dir.join("fig2_amg_tioga.csv").exists());
    let f3 = figures::fig3(&t, Some(fig_dir)).unwrap();
    assert!(f3.contains("source ranks"));
    let f6 = figures::fig6(&t, Some(fig_dir)).unwrap();
    assert!(f6.contains("bytes/sec"));
    // fig4/fig5 need laghos/dane; they must degrade gracefully
    let f4 = figures::fig4(&t, Some(fig_dir)).unwrap();
    assert!(f4.contains("no laghos runs"));

    // reload from disk and check metric derivations
    let t2 = commscope::coordinator::campaign::load_profiles(&dir).unwrap();
    assert_eq!(t2.len(), 6);
    for run in &t2.runs {
        assert!(stats::bandwidth_per_proc(run).unwrap() > 0.0);
        assert!(stats::message_rate_per_proc(run).unwrap() > 0.0);
    }
    // per-level series survive serialization
    let amg = t2.filter(&[("app", "amg2023"), ("ranks", "16")]);
    let levels = stats::amg_per_level(&amg.runs[0], |r| r.bytes_sent.avg());
    assert!(levels.len() >= 2);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn campaign_cache_reuses_profiles() {
    let dir = tmpdir("cache");
    let mut opts = CampaignOptions::new(&dir);
    opts.run = RunOptions {
        iter_shrink: 10,
        size_shrink: 8,
        ..Default::default()
    };
    opts.app = Some(AppKind::Kripke);
    opts.system = Some(SystemId::Tioga);
    opts.max_ranks = Some(8);
    opts.verbose = false;
    let t1 = run_campaign(&opts, true).unwrap();
    let path = dir.join("profiles/kripke_tioga_8.json");
    let mtime1 = std::fs::metadata(&path).unwrap().modified().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(30));
    let t2 = run_campaign(&opts, false).unwrap();
    let mtime2 = std::fs::metadata(&path).unwrap().modified().unwrap();
    assert_eq!(mtime1, mtime2, "cached profile must not be rewritten");
    assert_eq!(t1.len(), t2.len());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn deterministic_profiles_on_disk() {
    // Same cell run twice → byte-identical JSON (determinism contract).
    let dir_a = tmpdir("det_a");
    let dir_b = tmpdir("det_b");
    for d in [&dir_a, &dir_b] {
        let mut opts = CampaignOptions::new(d);
        opts.run = RunOptions {
            iter_shrink: 10,
            size_shrink: 8,
            ..Default::default()
        };
        opts.app = Some(AppKind::Amg2023);
        opts.system = Some(SystemId::Dane);
        opts.max_ranks = Some(64);
        opts.verbose = false;
        run_campaign(&opts, true).unwrap();
    }
    let a = std::fs::read_to_string(dir_a.join("profiles/amg2023_dane_64.json")).unwrap();
    let b = std::fs::read_to_string(dir_b.join("profiles/amg2023_dane_64.json")).unwrap();
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}
