//! Determinism-contract regression gate (docs/DETERMINISM.md): repeated
//! runs of the same cell are *byte-stable* — profile JSON and trace JSONL
//! never vary across invocations, on either engine.
//!
//! This is the artifact-level teeth behind the `hash-iter-artifact` lint
//! rule: a hash-ordered container leaking into an artifact path typically
//! still passes a single engine-equivalence comparison (both sides iterate
//! the same map state) but flickers across *process-internal repetitions*
//! as the maps' insertion histories and capacities drift. Ten repetitions
//! with fresh state each time catch exactly that class.

use commscope::benchpark::experiment::{ExperimentSpec, Scaling};
use commscope::benchpark::runner::{run_cell_full, RunOptions};
use commscope::benchpark::{AppKind, SystemId};
use commscope::caliper::ChannelConfig;
use commscope::mpisim::Engine;
use commscope::trace::write_jsonl;

const REPS: usize = 10;

fn spec() -> ExperimentSpec {
    ExperimentSpec {
        app: AppKind::Amg2023,
        system: SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks: 8,
    }
}

fn opts(engine: Engine) -> RunOptions {
    RunOptions {
        engine,
        iter_shrink: 1,
        size_shrink: 1,
        channels: ChannelConfig::parse("comm-stats,mpi-time,trace").unwrap(),
        ..Default::default()
    }
}

fn artifacts(engine: Engine) -> (String, String) {
    let out = run_cell_full(&spec(), &opts(engine)).unwrap();
    let profile = out.profile.to_json().to_string_pretty();
    let trace = write_jsonl(out.trace.as_ref().expect("trace artifact"));
    (profile, trace)
}

fn assert_byte_stable(engine: Engine, label: &str) {
    let (profile0, trace0) = artifacts(engine);
    assert!(!profile0.is_empty() && !trace0.is_empty());
    for rep in 1..REPS {
        let (profile, trace) = artifacts(engine);
        assert_eq!(
            profile0, profile,
            "{label}: profile bytes drifted on repetition {rep}"
        );
        assert_eq!(
            trace0, trace,
            "{label}: trace bytes drifted on repetition {rep}"
        );
    }
}

/// Threaded engine: 10 repeated runs of the rendezvous-heavy AMG cell
/// produce identical artifact bytes.
#[test]
fn threaded_artifacts_byte_stable_across_runs() {
    assert_byte_stable(Engine::Threaded, "threaded");
}

/// Event engine with 2 workers — real scheduling nondeterminism in wall
/// time, none allowed in the artifacts.
#[test]
fn event_artifacts_byte_stable_across_runs() {
    assert_byte_stable(Engine::parse("event:2").unwrap(), "event:2");
}
