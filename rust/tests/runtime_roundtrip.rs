//! Integration: the Rust PJRT runtime loads the HLO-text artifacts emitted
//! by `python/compile/aot.py` and reproduces the Python-side numerics.
//!
//! Requires `make artifacts` (the Makefile runs it before tests). The
//! reference values below mirror the schemes in
//! `python/compile/kernels/ref.py` exactly.

use commscope::runtime::{ComputeService, Executor};

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

/// Deterministic pseudo-random fill matching nothing in particular — the
/// comparison is against a Rust re-implementation of the same scheme, so
/// any values work.
fn fill(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = commscope::util::rng::Rng::new(seed);
    (0..n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
}

/// Rust mirror of ref.jacobi_step_ref (omega=0.8, h2=1).
fn jacobi_ref(u: &[f32], f: &[f32], n: usize) -> Vec<f32> {
    let nh = n + 2;
    let idx = |x: usize, y: usize, z: usize| (x * nh + y) * nh + z;
    let fidx = |x: usize, y: usize, z: usize| (x * n + y) * n + z;
    let mut out = vec![0f32; n * n * n];
    for x in 0..n {
        for y in 0..n {
            for z in 0..n {
                let (hx, hy, hz) = (x + 1, y + 1, z + 1);
                let c = u[idx(hx, hy, hz)];
                let nbr = u[idx(hx - 1, hy, hz)]
                    + u[idx(hx + 1, hy, hz)]
                    + u[idx(hx, hy - 1, hz)]
                    + u[idx(hx, hy + 1, hz)]
                    + u[idx(hx, hy, hz - 1)]
                    + u[idx(hx, hy, hz + 1)];
                let jac = (nbr + f[fidx(x, y, z)]) / 6.0;
                out[fidx(x, y, z)] = 0.2 * c + 0.8 * jac;
            }
        }
    }
    out
}

#[test]
fn amg_jacobi_matches_native_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let exec = Executor::load(dir).expect("loading artifacts");
    assert!(exec.platform().to_lowercase().contains("cpu") || !exec.platform().is_empty());
    let n = 16usize;
    let u = fill((n + 2) * (n + 2) * (n + 2), 1);
    let f = fill(n * n * n, 2);
    let outs = exec.execute_f32("amg_jacobi", &[&u, &f]).unwrap();
    assert_eq!(outs.len(), 1);
    let want = jacobi_ref(&u, &f, n);
    assert_eq!(outs[0].len(), want.len());
    for (a, b) in outs[0].iter().zip(&want) {
        assert!((a - b).abs() < 1e-4, "{} vs {}", a, b);
    }
}

#[test]
fn amg_residual_norm_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let exec = Executor::load(dir).unwrap();
    let n = 16usize;
    let u = fill((n + 2) * (n + 2) * (n + 2), 3);
    let f = fill(n * n * n, 4);
    let outs = exec.execute_f32("amg_residual", &[&u, &f]).unwrap();
    assert_eq!(outs.len(), 2);
    let r = &outs[0];
    let norm2 = outs[1][0];
    let sum: f32 = r.iter().map(|x| x * x).sum();
    assert!(
        (sum - norm2).abs() <= 1e-3 * norm2.abs().max(1.0),
        "norm mismatch {} vs {}",
        sum,
        norm2
    );
}

#[test]
fn kripke_sweep_equilibrium_fixed_point() {
    // At psi_in = q/sigt on all faces the DD update is a fixed point
    // (same property tested python-side).
    let Some(dir) = artifacts_dir() else { return };
    let exec = Executor::load(dir).unwrap();
    let (nx, ny, nz, g, d) = (8usize, 8usize, 8usize, 8usize, 8usize);
    let sig = vec![2.0f32; nx * ny * nz];
    let eq = vec![0.5f32; ny * nz * g * d]; // q=1.0 default, q/sigt = 0.5
    let outs = exec
        .execute_f32("kripke_sweep", &[&eq, &eq, &eq, &sig])
        .unwrap();
    assert_eq!(outs.len(), 4);
    for v in &outs[0] {
        assert!((v - 0.5).abs() < 1e-5, "psi_out_x {}", v);
    }
    // phi = mean over directions = 0.5 everywhere
    for v in &outs[3] {
        assert!((v - 0.5).abs() < 1e-5, "phi {}", v);
    }
}

#[test]
fn laghos_forces_matches_einsum() {
    let Some(dir) = artifacts_dir() else { return };
    let exec = Executor::load(dir).unwrap();
    let (e, q, n, dim) = (64usize, 16usize, 16usize, 2usize);
    let b = fill(e * q * n, 7);
    let s = fill(e * q * dim, 8);
    let outs = exec.execute_f32("laghos_forces", &[&b, &s]).unwrap();
    assert_eq!(outs.len(), 2);
    let forces = &outs[0];
    // spot-check a handful of entries against the contraction
    let fref = |ei: usize, ni: usize, di: usize| -> f32 {
        (0..q)
            .map(|qi| b[(ei * q + qi) * n + ni] * s[(ei * q + qi) * dim + di])
            .sum()
    };
    for &(ei, ni, di) in &[(0, 0, 0), (5, 3, 1), (63, 15, 1), (17, 9, 0)] {
        let got = forces[(ei * n + ni) * dim + di];
        let want = fref(ei, ni, di);
        assert!((got - want).abs() < 1e-3, "{} vs {}", got, want);
    }
    // wavespeed = max |stress|
    let ws = outs[1][0];
    let max_abs = s.iter().fold(0f32, |m, x| m.max(x.abs()));
    assert!((ws - max_abs).abs() < 1e-6);
}

#[test]
fn compute_service_cross_thread() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = ComputeService::start(dir).unwrap();
    let h = svc.handle();
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let h = h.clone();
            std::thread::spawn(move || {
                let n = 16usize;
                let u = fill((n + 2) * (n + 2) * (n + 2), 100 + i);
                let f = fill(n * n * n, 200 + i);
                let outs = h.execute("amg_jacobi", vec![u.clone(), f.clone()]).unwrap();
                let want = jacobi_ref(&u, &f, n);
                for (a, b) in outs[0].iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4);
                }
            })
        })
        .collect();
    for t in handles {
        t.join().unwrap();
    }
}

#[test]
fn executor_validates_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let exec = Executor::load(dir).unwrap();
    let bad = vec![0f32; 10];
    let f = vec![0f32; 16 * 16 * 16];
    assert!(exec.execute_f32("amg_jacobi", &[&bad, &f]).is_err());
    assert!(exec.execute_f32("amg_jacobi", &[&f]).is_err());
    assert!(exec.execute_f32("no_such_model", &[]).is_err());
}
