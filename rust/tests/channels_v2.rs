//! Integration tests for the Caliper v2 surface: metric channels, the
//! rank×rank comm matrix, RAII region guards, channel-spec parsing, and
//! the schema-v2 profile round-trip (including v1 migration).

use std::collections::BTreeMap;

use commscope::benchpark::experiment::{ExperimentSpec, Scaling};
use commscope::benchpark::runner::{run_cell, RunOptions};
use commscope::benchpark::{AppKind, SystemId};
use commscope::caliper::aggregate::{aggregate, check_matrix_conservation};
use commscope::caliper::{Caliper, ChannelConfig, RunProfile};
use commscope::mpisim::{MachineModel, World, WorldConfig};
use commscope::util::json::Json;

fn cfg(n: usize) -> WorldConfig {
    WorldConfig::new(n, MachineModel::test_machine())
}

/// Every rank sends a distinct payload to every other rank inside a comm
/// region; the aggregated matrix must be fully populated and conserved.
#[test]
fn comm_matrix_conservation_all_to_all() {
    let n = 6;
    let profiles = World::run(cfg(n), |rank| {
        let cali = Caliper::attach_with(rank, "comm-stats,comm-matrix").unwrap();
        let world = rank.world();
        {
            let _x = cali.comm_region("exchange");
            for dst in (0..n).filter(|&d| d != rank.rank) {
                // payload size encodes (src, dst) so cells are distinct
                let len = 8 * (1 + rank.rank * n + dst);
                let _ = rank.isend(&vec![0u8; len], dst, 7, &world).unwrap();
            }
            for src in (0..n).filter(|&s| s != rank.rank) {
                let _ = rank.recv::<u8>(Some(src), 7, &world).unwrap();
            }
        }
        cali.finish(rank)
    });
    let run = aggregate(BTreeMap::new(), &profiles);
    let m = run.regions["exchange"].comm_matrix.as_ref().unwrap();
    check_matrix_conservation(m).unwrap();
    assert_eq!(m.sent.len(), n * (n - 1));
    // row sums of sent bytes == column sums of received bytes, per rank
    let rows = m.sent_row_sums();
    let cols = m.recv_col_sums();
    for r in 0..n {
        let sent_by_r = rows[&r];
        let recv_by_r = cols[&r];
        let expect_sent: u64 = (0..n)
            .filter(|&d| d != r)
            .map(|d| 8 * (1 + r * n + d) as u64)
            .sum();
        let expect_recv: u64 = (0..n)
            .filter(|&s| s != r)
            .map(|s| 8 * (1 + s * n + r) as u64)
            .sum();
        assert_eq!(sent_by_r, expect_sent, "rank {} sent", r);
        assert_eq!(recv_by_r, expect_recv, "rank {} recv", r);
        // and every individual cell carries the encoded size
        for d in (0..n).filter(|&d| d != r) {
            assert_eq!(m.sent[&(r, d)], (1, 8 * (1 + r * n + d) as u64));
        }
    }
}

#[test]
fn guard_drop_order_builds_nested_paths() {
    let profiles = World::run(cfg(1), |rank| {
        let cali = Caliper::attach(rank);
        {
            let _a = cali.region("a");
            rank.advance(1.0);
            {
                let _b = cali.comm_region("b");
                rank.advance(2.0);
                let _c = cali.region("c");
                rank.advance(4.0);
                // _c then _b drop here, innermost first
            }
            rank.advance(8.0);
        }
        cali.finish(rank)
    });
    let p = &profiles[0];
    assert!((p.regions["a"].time_incl - 15.0).abs() < 1e-12);
    assert!((p.regions["a/b"].time_incl - 6.0).abs() < 1e-12);
    assert!((p.regions["a/b/c"].time_incl - 4.0).abs() < 1e-12);
    assert!(p.regions["a/b"].is_comm_region);
    assert!(!p.regions["a/b/c"].is_comm_region);
    assert!(!p.regions.keys().any(|k| k.contains("!unclosed")));
}

/// Guards must close their regions during a panic unwind, so a profile
/// survives `catch_unwind` without flagged leaks.
#[test]
fn guards_are_panic_safe() {
    let profiles = World::run(cfg(1), |rank| {
        let cali = Caliper::attach(rank);
        for attempt in 0..3 {
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _outer = cali.region("attempt");
                let _comm = cali.comm_region("risky_comm");
                if attempt < 2 {
                    panic!("injected failure {}", attempt);
                }
            }));
            assert_eq!(caught.is_err(), attempt < 2);
        }
        cali.finish(rank)
    });
    let p = &profiles[0];
    // all three attempts closed cleanly — two via unwinding drops
    assert_eq!(p.regions["attempt"].visits, 3);
    assert_eq!(p.regions["attempt/risky_comm"].visits, 3);
    assert!(!p.regions.keys().any(|k| k.contains("!unclosed")));
}

#[test]
fn channel_spec_errors_are_actionable() {
    // typo with a near-miss suggestion
    let err = ChannelConfig::parse("comm-stats,com-matrix").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("com-matrix"), "{}", msg);
    assert!(msg.contains("did you mean 'comm-matrix'"), "{}", msg);
    assert!(msg.contains("valid channels"), "{}", msg);
    // totally unknown name still lists the menu
    let err = ChannelConfig::parse("wharrgarbl").unwrap_err();
    assert!(err.to_string().contains("region-times"), "{}", err);
    // attach_with surfaces the same error
    World::run(cfg(1), |rank| {
        let err = Caliper::attach_with(rank, "nope").unwrap_err();
        assert_eq!(err.token, "nope");
    });
}

/// A v1-era profile document (no schema key, lossy min/max/avg/total
/// metrics) still loads, and re-saving it produces a valid v2 document.
#[test]
fn v1_profile_migrates_to_v2() {
    let v1_text = r#"{
        "meta": {"app": "laghos", "ranks": "4", "system": "dane"},
        "regions": {
            "main": {
                "comm_region": false,
                "participants": 4,
                "visits": 4,
                "time": {"min": 9.0, "max": 11.0, "avg": 10.0, "total": 40.0}
            },
            "main/halo_exchange": {
                "comm_region": true,
                "participants": 4,
                "visits": 16,
                "sends": {"min": 2, "max": 6, "avg": 4, "total": 16},
                "bytes_sent": {"min": 128, "max": 512, "avg": 256, "total": 1024},
                "max_send": 512,
                "min_send": 128
            },
            "main/load_skew": {
                "comm_region": false,
                "participants": 4,
                "visits": 4,
                "time": {"min": -1.5, "max": 1.5, "avg": 0.0, "total": 0.0}
            }
        }
    }"#;
    let v1 = RunProfile::from_json(&Json::parse(v1_text).unwrap()).unwrap();
    assert_eq!(v1.meta["app"], "laghos");
    let halo = &v1.regions["main/halo_exchange"];
    assert_eq!(halo.sends.min(), 2.0);
    assert_eq!(halo.sends.max(), 6.0);
    assert_eq!(halo.sends.avg(), 4.0);
    assert_eq!(halo.sends.total(), 16.0);
    assert_eq!(halo.sends.count(), 4);
    assert!((v1.wall_time() - 11.0).abs() < 1e-12);
    // a zero-mean metric must not divide by zero or clobber its extremes
    let skew = &v1.regions["main/load_skew"].time;
    assert_eq!(skew.min(), -1.5);
    assert_eq!(skew.max(), 1.5);
    assert_eq!(skew.total(), 0.0);
    assert_eq!(skew.count(), 2);

    // migrate: write as v2, read back, exact values preserved
    let v2_text = v1.to_json().to_string_pretty();
    assert!(v2_text.contains("\"schema\": 2"), "{}", &v2_text[..100]);
    let v2 = RunProfile::from_json(&Json::parse(&v2_text).unwrap()).unwrap();
    let halo2 = &v2.regions["main/halo_exchange"];
    assert_eq!(halo2.sends.min().to_bits(), halo.sends.min().to_bits());
    assert_eq!(halo2.sends.max().to_bits(), halo.sends.max().to_bits());
    assert_eq!(halo2.sends.avg().to_bits(), halo.sends.avg().to_bits());
    assert_eq!(halo2.sends.total().to_bits(), halo.sends.total().to_bits());
    assert_eq!(halo2.sends.count(), halo.sends.count());
}

/// End-to-end: a real experiment cell run with every channel produces a
/// schema-v2 profile that round-trips byte-identically — the disk-cache
/// contract (`write(parse(write(p))) == write(p)`).
#[test]
fn v2_roundtrip_byte_identical_through_cell_runner() {
    let spec = ExperimentSpec {
        app: AppKind::Amg2023,
        system: SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks: 8,
    };
    let opts = RunOptions {
        iter_shrink: 10,
        size_shrink: 8,
        channels: ChannelConfig::parse("all").unwrap(),
        ..Default::default()
    };
    let run = run_cell(&spec, &opts).unwrap();
    let all_spec = ChannelConfig::parse("all").unwrap().spec_string();
    assert_eq!(run.meta["channels"], all_spec);
    let text1 = run.to_json().to_string_pretty();
    let reparsed = RunProfile::from_json(&Json::parse(&text1).unwrap()).unwrap();
    let text2 = reparsed.to_json().to_string_pretty();
    assert_eq!(text1, text2, "schema-v2 disk round-trip must be byte-identical");

    // the halo region carries its matrix, and it is conserved
    let halo = run.region("matvec_comm_level_0").unwrap().1;
    let m = halo.comm_matrix.as_ref().expect("comm-matrix channel on");
    check_matrix_conservation(m).unwrap();
    // mpi-time exists and is positive (overlapping posted receives can
    // legitimately sum past the region's elapsed span, so no upper bound)
    let mt = halo.mpi_time.as_ref().expect("mpi-time channel on");
    assert!(mt.max() > 0.0);
    // msg-hist agrees with the comm-stats extremes
    let h = halo.msg_hist.as_ref().expect("msg-hist channel on");
    assert_eq!(h.send.min, halo.min_send);
    assert_eq!(h.send.max, halo.max_send);
    assert_eq!(h.send.count as f64, halo.sends.total());
}

/// The default channel set reproduces the v1 profiler's output exactly —
/// migration must not change any existing figure input.
#[test]
fn default_channels_match_v1_output() {
    let spec = ExperimentSpec {
        app: AppKind::Kripke,
        system: SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks: 8,
    };
    let opts = RunOptions {
        iter_shrink: 10,
        size_shrink: 8,
        ..Default::default()
    };
    let run = run_cell(&spec, &opts).unwrap();
    let sweep = run.region("sweep_comm").unwrap().1;
    assert!(sweep.sends.total() > 0.0);
    assert!(sweep.comm_matrix.is_none(), "not requested");
    assert!(sweep.msg_hist.is_none());
    assert!(sweep.mpi_time.is_none());
}
