//! The parallel campaign executor's contract: a parallel run of the smoke
//! matrix is byte-identical to a serial run (determinism), work actually
//! spreads over >1 worker, repeated cells are served from the dedup cache,
//! and the disk campaign writes the same artifacts at any `--jobs` width.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use commscope::benchpark::runner::RunOptions;
use commscope::benchpark::{AppKind, SystemId};
use commscope::coordinator::campaign::{
    run_campaign_report, selected_cells, CampaignExecutor, CampaignOptions,
};

fn fast() -> RunOptions {
    RunOptions {
        iter_shrink: 10,
        size_shrink: 8,
        ..Default::default()
    }
}

/// The ≤16-rank smoke matrix: amg2023/kripke/zmodel tioga 8/16.
fn smoke_cells() -> Vec<commscope::benchpark::ExperimentSpec> {
    let mut opts = CampaignOptions::new(std::env::temp_dir());
    opts.max_ranks = Some(16);
    let cells = selected_cells(&opts);
    assert_eq!(cells.len(), 6);
    cells
}

#[test]
fn parallel_profiles_byte_identical_to_serial() {
    let cells = smoke_cells();
    let serial = CampaignExecutor::new(1, fast()).unwrap().execute(&cells);
    // `workers_used` is scheduling-dependent: on a contended runner one
    // worker can in principle steal the whole batch. Retry a couple of
    // times (fresh executor each time, so cells really re-run) before
    // declaring the pool serial — three collapses in a row means a bug.
    let mut parallel = CampaignExecutor::new(4, fast()).unwrap().execute(&cells);
    for _ in 0..2 {
        if parallel.workers_used > 1 {
            break;
        }
        parallel = CampaignExecutor::new(4, fast()).unwrap().execute(&cells);
    }
    assert!(serial.failures.is_empty() && parallel.failures.is_empty());
    assert_eq!(serial.runs.len(), 6);
    assert_eq!(parallel.runs.len(), 6);
    assert_eq!(parallel.workers, 4);
    assert!(
        parallel.workers_used > 1,
        "expected >1 worker thread, report: {}",
        parallel.summary()
    );
    for (s, p) in serial.runs.iter().zip(&parallel.runs) {
        assert_eq!(s.profile.meta, p.profile.meta);
        let sj = s.profile.to_json().to_string_pretty();
        let pj = p.profile.to_json().to_string_pretty();
        assert_eq!(
            sj,
            pj,
            "profile for {:?} diverged",
            s.profile.meta.get("app")
        );
    }
}

#[test]
fn dedup_cache_serves_repeated_cells() {
    let cells = smoke_cells();
    let exec = CampaignExecutor::new(4, fast()).unwrap();
    // The same 6 unique cells, each listed three times.
    let mut tripled = Vec::new();
    for _ in 0..3 {
        tripled.extend_from_slice(&cells);
    }
    let executed = AtomicUsize::new(0);
    let report = exec.execute_with(&tripled, |_, _| {
        executed.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(report.cells_total, 18);
    assert_eq!(report.cells_executed, 6, "{}", report.summary());
    assert_eq!(report.cache_hits, 12, "{}", report.summary());
    assert_eq!(executed.load(Ordering::Relaxed), 6, "sink fires once per unique cell");
    assert_eq!(report.runs.len(), 6, "duplicates collapse in the output");
    // In-memory thicket assembly: canonical (app, system, ranks) order.
    let t = report.thicket();
    assert_eq!(t.len(), 6);
    let order: Vec<String> = t
        .runs
        .iter()
        .map(|r| format!("{}_{}", r.meta["app"], r.meta["ranks"]))
        .collect();
    assert_eq!(
        order,
        ["amg2023_8", "amg2023_16", "kripke_8", "kripke_16", "zmodel_8", "zmodel_16"]
    );

    // A follow-up campaign of already-seen cells is pure cache.
    let again = exec.execute(&cells);
    assert_eq!(again.cells_executed, 0);
    assert_eq!(again.cache_hits, 6);
    for (a, b) in report.runs.iter().zip(&again.runs) {
        assert!(Arc::ptr_eq(a, b), "cached cells must share one allocation");
    }
    let stats = exec.cache_stats();
    assert_eq!(stats.entries, 6);
    assert!(stats.hits >= 4, "cache hit counter must register: {:?}", stats);
}

#[test]
fn disk_campaign_identical_across_jobs_widths() {
    let base = std::env::temp_dir().join(format!("campaign_par_{}", std::process::id()));
    let dir_serial = base.join("serial");
    let dir_parallel = base.join("parallel");
    for (dir, jobs) in [(&dir_serial, 1usize), (&dir_parallel, 3usize)] {
        let mut opts = CampaignOptions::new(dir);
        opts.run = fast();
        opts.app = Some(AppKind::Kripke);
        opts.system = Some(SystemId::Tioga);
        opts.max_ranks = Some(16);
        opts.verbose = false;
        opts.jobs = jobs;
        let (t, report) = run_campaign_report(&opts, true).unwrap();
        assert_eq!(t.len(), 2);
        assert!(report.failures.is_empty());
        assert_eq!(report.cells_executed, 2);
    }
    for cell in ["kripke_tioga_8", "kripke_tioga_16"] {
        let a = std::fs::read_to_string(dir_serial.join(format!("profiles/{}.json", cell)))
            .unwrap();
        let b = std::fs::read_to_string(dir_parallel.join(format!("profiles/{}.json", cell)))
            .unwrap();
        assert_eq!(a, b, "{} differs between --jobs 1 and --jobs 3", cell);
    }
    std::fs::remove_dir_all(&base).ok();
}

/// The trace subsystem's determinism contract: for the same cell, a
/// `--jobs 1` and a `--jobs 4` campaign write byte-for-byte identical
/// trace artifacts (and the in-memory traces match too).
#[test]
fn trace_artifacts_byte_identical_across_jobs_widths() {
    use commscope::caliper::ChannelConfig;
    let traced = RunOptions {
        iter_shrink: 10,
        size_shrink: 8,
        channels: ChannelConfig::parse("comm-stats,trace").unwrap(),
        ..Default::default()
    };
    let base = std::env::temp_dir().join(format!("trace_par_{}", std::process::id()));
    let dir_serial = base.join("serial");
    let dir_parallel = base.join("parallel");
    for (dir, jobs) in [(&dir_serial, 1usize), (&dir_parallel, 4usize)] {
        let mut opts = CampaignOptions::new(dir);
        opts.run = traced;
        opts.max_ranks = Some(16);
        opts.verbose = false;
        opts.jobs = jobs;
        let (t, report) = run_campaign_report(&opts, true).unwrap();
        assert_eq!(t.len(), 6);
        assert!(report.failures.is_empty(), "{}", report.summary());
        // the campaign retains profiles, not event streams — traces are
        // streamed straight to the on-disk artifacts (checked below)
        for run in &report.runs {
            assert!(run.trace.is_none(), "cached cells must drop the stream");
        }
    }
    let mut compared = 0;
    for cell in [
        "amg2023_tioga_8",
        "amg2023_tioga_16",
        "kripke_tioga_8",
        "kripke_tioga_16",
        "zmodel_tioga_8",
        "zmodel_tioga_16",
    ] {
        let name = format!("traces/{}.trace.jsonl", cell);
        let a = std::fs::read_to_string(dir_serial.join(&name)).unwrap();
        let b = std::fs::read_to_string(dir_parallel.join(&name)).unwrap();
        assert_eq!(a, b, "{} trace differs between --jobs 1 and --jobs 4", cell);
        assert!(
            commscope::trace::read_jsonl(&a).is_some(),
            "{} artifact parses",
            cell
        );
        compared += 1;
    }
    assert_eq!(compared, 6);
    // a re-run without --force treats profile+trace as disk-cached
    let mut opts = CampaignOptions::new(&dir_serial);
    opts.run = traced;
    opts.max_ranks = Some(16);
    opts.verbose = false;
    let (_, again) = run_campaign_report(&opts, false).unwrap();
    assert_eq!(again.disk_cached, 6, "{}", again.summary());
    assert_eq!(again.cells_executed, 0);
    // deleting one trace artifact makes that cell stale even though its
    // profile is still on disk
    std::fs::remove_file(dir_serial.join("traces/kripke_tioga_8.trace.jsonl")).unwrap();
    let (_, partial) = run_campaign_report(&opts, false).unwrap();
    assert_eq!(partial.disk_cached, 5, "{}", partial.summary());
    assert_eq!(partial.cells_executed, 1);
    assert!(dir_serial.join("traces/kripke_tioga_8.trace.jsonl").is_file());
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn executor_validates_options_before_running() {
    let bad = RunOptions {
        iter_shrink: 1,
        size_shrink: 0,
        ..Default::default()
    };
    let err = CampaignExecutor::new(2, bad).unwrap_err().to_string();
    assert!(err.contains("campaign run options"), "err: {}", err);
}
