//! Failure-injection tests: the runtime must turn application bugs into
//! diagnosable errors, not hangs or silent corruption.

use std::time::Duration;

use commscope::mpisim::collectives::ReduceOp;
use commscope::mpisim::{MachineModel, MpiError, World, WorldConfig};

fn quick_cfg(n: usize) -> WorldConfig {
    WorldConfig::new(n, MachineModel::test_machine())
        .with_timeout(Duration::from_millis(300))
}

#[test]
fn recv_without_sender_times_out_with_context() {
    let errs = World::run(quick_cfg(2), |rank| {
        let world = rank.world();
        if rank.rank == 0 {
            // rank 1 never sends tag 42
            match rank.recv::<f64>(Some(1), 42, &world) {
                Err(MpiError::RecvTimeout { rank: r, tag, .. }) => {
                    assert_eq!(r, 0);
                    assert_eq!(tag, 42);
                    true
                }
                other => panic!("expected RecvTimeout, got {:?}", other.map(|_| ())),
            }
        } else {
            true
        }
    });
    assert!(errs.iter().all(|&e| e));
}

#[test]
fn collective_straggler_times_out_with_counts() {
    let results = World::run(quick_cfg(4), |rank| {
        let world = rank.world();
        if rank.rank == 3 {
            // deserter: never joins the barrier
            return None;
        }
        match rank.barrier(&world) {
            Err(MpiError::CollectiveTimeout {
                arrived, expected, ..
            }) => Some((arrived, expected)),
            other => panic!("expected CollectiveTimeout, got {:?}", other),
        }
    });
    for r in results.into_iter().flatten() {
        assert_eq!(r.1, 4);
        assert!(r.0 <= 3);
    }
}

#[test]
fn mismatched_collectives_detected() {
    let flags = World::run(quick_cfg(2), |rank| {
        let world = rank.world();
        if rank.rank == 0 {
            match rank.barrier(&world) {
                // rank 1 called allreduce on the same slot: whoever arrives
                // second sees the mismatch; the first may instead time out.
                Err(MpiError::CollectiveMismatch { .. })
                | Err(MpiError::CollectiveTimeout { .. }) => true,
                other => panic!("rank0: unexpected {:?}", other),
            }
        } else {
            match rank.allreduce_f64(&[1.0], ReduceOp::Sum, &world) {
                Err(MpiError::CollectiveMismatch { .. })
                | Err(MpiError::CollectiveTimeout { .. }) => true,
                other => panic!("rank1: unexpected {:?}", other.map(|_| ())),
            }
        }
    });
    assert!(flags.iter().all(|&f| f));
}

#[test]
fn wrong_payload_type_detected() {
    World::run(quick_cfg(2), |rank| {
        let world = rank.world();
        if rank.rank == 0 {
            // 10 bytes is not a whole number of f64s
            rank.send(&[1u8; 10], 1, 0, &world).unwrap();
        } else {
            let err = rank.recv::<f64>(Some(0), 0, &world).unwrap_err();
            assert!(matches!(err, MpiError::PayloadSizeMismatch { got: 10, elem: 8 }));
        }
    });
}

#[test]
fn rank_out_of_range_on_every_surface() {
    World::run(quick_cfg(2), |rank| {
        let world = rank.world();
        assert!(matches!(
            rank.send(&[0.0f64], 7, 0, &world),
            Err(MpiError::RankOutOfRange { rank: 7, .. })
        ));
        assert!(matches!(
            rank.irecv(Some(9), 0, &world),
            Err(MpiError::RankOutOfRange { rank: 9, .. })
        ));
    });
}

#[test]
#[allow(deprecated)] // leaking regions requires the paired v1 calls —
                     // guards cannot outlive `finish` by construction
fn unclosed_caliper_region_is_flagged_not_lost() {
    use commscope::caliper::Caliper;
    let profiles = World::run(quick_cfg(1), |rank| {
        let cali = Caliper::attach(rank);
        cali.begin(rank, "main");
        cali.comm_region_begin(rank, "leaky");
        rank.advance(1.0);
        cali.finish(rank)
    });
    let keys: Vec<&String> = profiles[0].regions.keys().collect();
    assert!(
        keys.iter().any(|k| k.contains("leaky!unclosed")),
        "keys: {:?}",
        keys
    );
    // time still attributed
    let leaky = profiles[0]
        .regions
        .iter()
        .find(|(k, _)| k.contains("leaky"))
        .unwrap()
        .1;
    assert!(leaky.time_incl >= 1.0);
}

#[test]
fn bad_cart_dims_rejected_not_hung() {
    use commscope::mpisim::cart::CartComm;
    World::run(quick_cfg(4), |rank| {
        let world = rank.world();
        let err = CartComm::new(world, &[3, 3, 3], &[false; 3]).unwrap_err();
        assert!(matches!(err, MpiError::BadCartDims { .. }));
    });
}

#[test]
fn empty_split_group_is_error() {
    // color chosen so one rank's group would be empty is impossible by
    // construction (each rank is in its own color's group); instead verify
    // split with distinct colors yields singleton comms that still work.
    let sizes = World::run(quick_cfg(3), |rank| {
        let world = rank.world();
        let sub = rank.comm_split(&world, rank.rank as u64, 0).unwrap();
        let s = rank
            .allreduce_f64(&[rank.rank as f64], ReduceOp::Sum, &sub)
            .unwrap();
        (sub.size(), s[0])
    });
    for (r, (size, sum)) in sizes.iter().enumerate() {
        assert_eq!(*size, 1);
        assert_eq!(*sum, r as f64);
    }
}

#[test]
fn runtime_missing_artifacts_fails_fast() {
    use commscope::runtime::{ComputeService, Executor};
    assert!(Executor::load("/nonexistent/place").is_err());
    assert!(ComputeService::start("/nonexistent/place").is_err());
}

#[test]
fn campaign_surfaces_cell_failures_without_aborting() {
    use commscope::benchpark::experiment::Scaling;
    use commscope::benchpark::runner::RunOptions;
    use commscope::benchpark::{AppKind, ExperimentSpec, SystemId};
    use commscope::coordinator::campaign::CampaignExecutor;

    // laghos on tioga is outside the paper's matrix → the runner rejects
    // it; the two valid cells around it must still run to completion.
    let bad = ExperimentSpec {
        app: AppKind::Laghos,
        system: SystemId::Tioga,
        scaling: Scaling::Strong,
        nranks: 8,
    };
    let good = |nranks| ExperimentSpec {
        app: AppKind::Kripke,
        system: SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks,
    };
    let exec = CampaignExecutor::new(
        2,
        RunOptions {
            iter_shrink: 10,
            size_shrink: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let report = exec.execute(&[good(8), bad, good(16)]);
    assert_eq!(report.cells_total, 3);
    assert_eq!(report.runs.len(), 2, "valid cells must survive");
    assert_eq!(report.failures.len(), 1);
    assert_eq!(report.failures[0].id, "laghos_tioga_8");
    assert!(
        report.failures[0].error.contains("dane"),
        "diagnosable error, got: {}",
        report.failures[0].error
    );
    // the failed cell is not poisoned into the cache: retrying re-fails,
    // and a duplicate of a failed cell claims no cache hit — it collapses
    // into the one failure record.
    let retry = exec.execute(&[bad, bad]);
    assert_eq!(retry.cells_total, 2);
    assert_eq!(retry.cells_executed, 0, "a failed cell is not 'executed'");
    assert_eq!(retry.failures.len(), 1);
    assert_eq!(retry.cache_hits, 0);
}
