//! Integration tests asserting the paper's qualitative findings hold on
//! reduced-scale runs — the "shape" contract of the reproduction
//! (EXPERIMENTS.md records the full-scale numbers).

use commscope::benchpark::experiment::{ExperimentSpec, Scaling};
use commscope::benchpark::runner::{run_cell, RunOptions};
use commscope::benchpark::{AppKind, SystemId};
use commscope::caliper::RunProfile;
use commscope::thicket::{stats, Thicket};

fn cell(app: AppKind, system: SystemId, nranks: usize, opts: &RunOptions) -> RunProfile {
    let spec = ExperimentSpec {
        app,
        system,
        scaling: if app == AppKind::Laghos {
            Scaling::Strong
        } else {
            Scaling::Weak
        },
        nranks,
    };
    run_cell(&spec, opts).expect("cell")
}

fn fast() -> RunOptions {
    RunOptions {
        iter_shrink: 5,
        size_shrink: 4,
        ..Default::default()
    }
}

#[test]
fn kripke_partner_counts_match_paper() {
    // §IV-A: 3..6 partners; smallest GPU run: all corners ⇒ exactly 3.
    let run = cell(AppKind::Kripke, SystemId::Tioga, 8, &fast());
    let sweep = run.region("sweep_comm").unwrap().1;
    assert_eq!(sweep.dest_ranks.min(), 3.0);
    assert_eq!(sweep.dest_ranks.max(), 3.0);
    let run64 = cell(AppKind::Kripke, SystemId::Tioga, 64, &fast());
    let sweep64 = run64.region("sweep_comm").unwrap().1;
    assert_eq!(sweep64.dest_ranks.min(), 3.0);
    assert_eq!(sweep64.dest_ranks.max(), 6.0);
}

#[test]
fn kripke_sends_per_edge_are_640_at_full_iters() {
    // Table IV invariant: 640 messages per directed edge (32/iter × 20).
    let opts = RunOptions {
        iter_shrink: 1,
        size_shrink: 8,
        ..Default::default()
    };
    let run = cell(AppKind::Kripke, SystemId::Tioga, 8, &opts);
    let sweep = run.region("sweep_comm").unwrap().1;
    // 2x2x2 ⇒ 24 directed edges ⇒ 15,360 total sends (Table IV Tioga-8).
    assert_eq!(sweep.sends.total(), 15_360.0);
}

#[test]
fn amg_level_count_grows_with_scale() {
    // §IV-B: larger runs have more MG levels.
    let opts = RunOptions {
        iter_shrink: 10,
        size_shrink: 1,
        ..Default::default()
    };
    let small = cell(AppKind::Amg2023, SystemId::Tioga, 8, &opts);
    let large = cell(AppKind::Amg2023, SystemId::Tioga, 64, &opts);
    let nl = |r: &RunProfile| r.regions_with_prefix("matvec_comm_level_").len();
    assert!(nl(&large) > nl(&small), "{} vs {}", nl(&large), nl(&small));
}

#[test]
fn amg_fine_levels_carry_most_bytes() {
    // Fig 2: level 0 ≫ coarsest level in bytes per process.
    let opts = RunOptions {
        iter_shrink: 5,
        size_shrink: 1,
        ..Default::default()
    };
    let run = cell(AppKind::Amg2023, SystemId::Dane, 64, &opts);
    let series = stats::amg_per_level(&run, |r| r.bytes_sent.max());
    assert!(series.len() >= 3);
    let first = series.first().unwrap().1;
    let last = series.last().unwrap().1;
    assert!(first > 10.0 * last, "fine {} vs coarse {}", first, last);
}

#[test]
fn amg_cpu_coarse_fanin_explodes_gpu_stays_bounded() {
    // Fig 3's core contrast, at 64 ranks: deep-level src fan-in is much
    // larger under the CPU strategy than the GPU strategy.
    let opts = RunOptions {
        iter_shrink: 10,
        size_shrink: 1,
        ..Default::default()
    };
    let dane = cell(AppKind::Amg2023, SystemId::Dane, 64, &opts);
    let tioga = cell(AppKind::Amg2023, SystemId::Tioga, 64, &opts);
    let deep_max = |r: &RunProfile| {
        stats::amg_per_level(r, |reg| reg.src_ranks.max())
            .into_iter()
            .map(|(_, v)| v)
            .fold(0.0f64, f64::max)
    };
    let d = deep_max(&dane);
    let t = deep_max(&tioga);
    assert!(d >= 4.0 * t, "dane fan-in {} vs tioga {}", d, t);
    assert!(t <= 8.0, "tioga fan-in should stay face-local, got {}", t);
}

#[test]
fn laghos_strong_scaling_shapes() {
    // Table IV Laghos rows: max send falls, total sends grow, per-rank
    // bytes fall.
    let opts = RunOptions {
        iter_shrink: 10,
        size_shrink: 4,
        ..Default::default()
    };
    let runs: Vec<RunProfile> = [16, 64]
        .into_iter()
        .map(|n| cell(AppKind::Laghos, SystemId::Dane, n, &opts))
        .collect();
    let (b16, s16, m16, _) = stats::table4_row(&runs[0]);
    let (b64, s64, m64, _) = stats::table4_row(&runs[1]);
    assert!(m16 > m64, "largest send must fall: {} vs {}", m16, m64);
    assert!(s64 > s16, "total sends must grow: {} vs {}", s16, s64);
    assert!(
        b16 / 16.0 > b64 / 64.0,
        "bytes per rank must fall: {} vs {}",
        b16 / 16.0,
        b64 / 64.0
    );
}

#[test]
fn dane_bandwidth_declines_tioga_rises_for_kripke() {
    // Fig 5 vs Fig 6 headline contrast.
    let opts = RunOptions {
        iter_shrink: 5,
        size_shrink: 2,
        ..Default::default()
    };
    let mk = |system, scales: [usize; 2]| {
        Thicket::new(
            scales
                .into_iter()
                .map(|n| cell(AppKind::Kripke, system, n, &opts))
                .collect(),
        )
    };
    let dane = mk(SystemId::Dane, [64, 256]);
    let tioga = mk(SystemId::Tioga, [8, 64]);
    let series = |t: &Thicket| t.series(stats::bandwidth_per_proc);
    let d = series(&dane);
    let t = series(&tioga);
    assert!(
        d.first().unwrap().1 > d.last().unwrap().1,
        "dane kripke bandwidth should decline: {:?}",
        d
    );
    assert!(
        t.last().unwrap().1 > t.first().unwrap().1 * 0.9,
        "tioga kripke bandwidth should not collapse: {:?}",
        t
    );
}

#[test]
fn kripke_is_bandwidth_king_amg_is_message_heavy() {
    // Fig 5: Kripke has the highest bytes/s/proc and the lowest msg rate.
    // Full per-rank problem sizes and full iteration counts (shrinking
    // either distorts the byte/time balance this test is about — e.g.
    // AMG's one-time setup phase amortizes over the solve iterations);
    // small rank count keeps it fast.
    let opts = RunOptions {
        iter_shrink: 1,
        size_shrink: 1,
        ..Default::default()
    };
    let kripke = cell(AppKind::Kripke, SystemId::Dane, 8, &opts);
    let amg = cell(AppKind::Amg2023, SystemId::Dane, 8, &opts);
    let bw_k = stats::bandwidth_per_proc(&kripke).unwrap();
    let bw_a = stats::bandwidth_per_proc(&amg).unwrap();
    assert!(bw_k > bw_a, "kripke bw {} vs amg {}", bw_k, bw_a);
    let avg_k = stats::table4_row(&kripke).3;
    let avg_a = stats::table4_row(&amg).3;
    assert!(
        avg_k > avg_a,
        "kripke avg msg {} should exceed amg {}",
        avg_k,
        avg_a
    );
}
