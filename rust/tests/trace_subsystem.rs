//! The trace subsystem's acceptance contract:
//!
//! - **Critical-path invariant**: for every traced cell, the extracted
//!   path length equals `RunProfile::wall_time` to float tolerance, and
//!   the per-region attribution sums to the total.
//! - **Wait-state classification**: a constructed late-sender exchange is
//!   classified as such with the correct wait duration; a rendezvous
//!   late-receiver exchange likewise.
//! - **Bounded memory**: a tiny `trace.max-events-per-rank` drops events
//!   with an explicit counter that reaches the artifact header and the
//!   profile metadata — never silent growth, never silent loss.
//! - **Artifact**: the JSONL trace round-trips losslessly and
//!   byte-stably.

use std::time::Duration;

use commscope::benchpark::experiment::Scaling;
use commscope::benchpark::runner::{run_cell_full, RunOptions};
use commscope::benchpark::{AppKind, ExperimentSpec, SystemId};
use commscope::caliper::{Caliper, ChannelConfig};
use commscope::mpisim::{MachineModel, World, WorldConfig};
use commscope::trace::{classify, critical_path, read_jsonl, write_jsonl, RunTrace, WaitKind};

fn traced_opts() -> RunOptions {
    RunOptions {
        iter_shrink: 10,
        size_shrink: 8,
        channels: ChannelConfig::parse("comm-stats,mpi-time,trace").unwrap(),
        ..Default::default()
    }
}

/// Run a 2-rank world with tracing and hand back the merged run trace.
fn run_traced_world<F>(n: usize, f: F) -> RunTrace
where
    F: Fn(&mut commscope::mpisim::Rank, &Caliper) + Sync,
{
    let cfg = WorldConfig::new(n, MachineModel::test_machine())
        .with_timeout(Duration::from_secs(20));
    let profiles = World::run(cfg, |rank| {
        let cali = Caliper::attach_with(rank, "comm-stats,trace").unwrap();
        f(rank, &cali);
        cali.finish(rank)
    });
    RunTrace::new(
        profiles
            .into_iter()
            .filter_map(|mut p| p.trace.take())
            .collect(),
    )
}

#[test]
fn critical_path_matches_wall_time_for_traced_cells() {
    for (app, system, nranks, scaling) in [
        (AppKind::Amg2023, SystemId::Tioga, 8, Scaling::Weak),
        (AppKind::Kripke, SystemId::Tioga, 8, Scaling::Weak),
        (AppKind::Laghos, SystemId::Dane, 4, Scaling::Strong),
        (AppKind::Zmodel, SystemId::Tioga, 8, Scaling::Weak),
    ] {
        let spec = ExperimentSpec {
            app,
            system,
            scaling,
            nranks,
        };
        let out = run_cell_full(&spec, &traced_opts()).unwrap();
        let trace = out.trace.as_ref().unwrap_or_else(|| {
            panic!("{}: trace channel enabled but no trace", app.name())
        });
        assert_eq!(trace.dropped_events(), 0, "{}: default ring too small", app.name());
        let cp = critical_path(trace).expect("nonempty trace");
        let wall = out.profile.wall_time();
        assert!(
            (cp.total - wall).abs() <= 1e-9 * wall.max(1.0),
            "{}: critical path {} != wall time {}",
            app.name(),
            cp.total,
            wall
        );
        let attributed: f64 = cp.per_region.values().sum();
        assert!(
            (attributed - cp.total).abs() <= 1e-9 * cp.total.max(1.0),
            "{}: per-region attribution {} != total {}",
            app.name(),
            attributed,
            cp.total
        );
        // the fold into the profile agrees with the analysis
        assert_eq!(
            out.profile.meta.get("trace_critpath").map(String::as_str),
            Some(format!("{}", cp.total).as_str()),
            "{}: meta stamp",
            app.name()
        );
        let folded: f64 = out
            .profile
            .regions
            .values()
            .filter_map(|r| r.trace.as_ref().map(|t| t.critpath))
            .sum();
        let unattributed: f64 = out
            .profile
            .meta
            .get("trace_critpath_unattributed")
            .and_then(|s| s.parse().ok())
            .unwrap_or(0.0);
        assert!(
            (folded + unattributed - cp.total).abs() <= 1e-6 * cp.total.max(1.0),
            "{}: folded {} + unattributed {} != {}",
            app.name(),
            folded,
            unattributed,
            cp.total
        );
    }
}

#[test]
fn constructed_late_sender_is_classified_with_correct_duration() {
    let m = MachineModel::test_machine();
    let delay = 1.0;
    let trace = run_traced_world(2, |rank, cali| {
        let world = rank.world();
        let _main = cali.region("main");
        if rank.rank == 0 {
            // the late sender: busy for `delay` before sending
            rank.advance(delay);
            rank.send(&[1.0f64; 8], 1, 7, &world).unwrap();
        } else {
            let _halo = cali.comm_region("halo");
            let (_data, _st) = rank.recv::<f64>(Some(0), 7, &world).unwrap();
        }
    });
    let states = classify(&trace);
    let late: Vec<_> = states
        .iter()
        .filter(|s| s.kind == WaitKind::LateSender)
        .collect();
    assert_eq!(late.len(), 1, "exactly one late-sender instance: {:?}", states);
    let ws = late[0];
    assert_eq!(ws.rank, 1, "the receiver idles");
    assert_eq!(ws.peer, Some(0));
    assert_eq!(ws.region, "main/halo", "attributed to the comm region");
    // The receiver posted at ~0; the sender was ready at
    // delay + send_overhead. Wait duration is exactly the gap.
    let expect = delay + m.net.send_overhead;
    assert!(
        (ws.duration - expect).abs() < 1e-12,
        "late-sender wait {} != {}",
        ws.duration,
        expect
    );
    // the idle span is also on the critical path through the sender
    let cp = critical_path(&trace).unwrap();
    assert_eq!(cp.hops, 1, "path hops through the message edge");
    assert!(cp.segments.iter().any(|s| s.rank == 0), "sender is on the path");
}

#[test]
fn constructed_late_receiver_is_classified_on_the_sender() {
    // Above-threshold message: rendezvous. The receiver posts late, so
    // the SENDER blocks in wait_send — a late-receiver wait state.
    let mut m = MachineModel::test_machine();
    m.net.eager_threshold = 1024;
    let delay = 0.75;
    let cfg = WorldConfig::new(2, m.clone()).with_timeout(Duration::from_secs(20));
    let profiles = World::run(cfg, |rank| {
        let cali = Caliper::attach_with(rank, "trace").unwrap();
        let world = rank.world();
        {
            let _main = cali.region("main");
            if rank.rank == 0 {
                let _push = cali.comm_region("push");
                let req = rank.isend(&vec![0u8; 4096], 1, 0, &world).unwrap();
                rank.wait_send(req).unwrap();
            } else {
                rank.advance(delay);
                let _ = rank.recv::<u8>(Some(0), 0, &world).unwrap();
            }
        }
        cali.finish(rank)
    });
    let trace = RunTrace::new(
        profiles
            .into_iter()
            .filter_map(|mut p| p.trace.take())
            .collect(),
    );
    let states = classify(&trace);
    let late: Vec<_> = states
        .iter()
        .filter(|s| s.kind == WaitKind::LateReceiver)
        .collect();
    assert_eq!(late.len(), 1, "one late-receiver instance: {:?}", states);
    let ws = late[0];
    assert_eq!(ws.rank, 0, "the sender idles");
    assert_eq!(ws.peer, Some(1));
    assert_eq!(ws.region, "main/push");
    // gate = receiver's post time (delay); sender was ready at
    // send_overhead — it idles for the difference.
    let expect = delay - m.net.send_overhead;
    assert!(
        (ws.duration - expect).abs() < 1e-12,
        "late-receiver wait {} != {}",
        ws.duration,
        expect
    );
}

#[test]
fn barrier_stagger_classifies_wait_at_collective() {
    let trace = run_traced_world(4, |rank, cali| {
        let world = rank.world();
        let _main = cali.region("main");
        rank.advance(rank.rank as f64); // rank 3 arrives last
        rank.barrier(&world).unwrap();
    });
    let states = classify(&trace);
    let coll: Vec<_> = states
        .iter()
        .filter(|s| s.kind == WaitKind::WaitAtCollective)
        .collect();
    assert_eq!(coll.len(), 3, "every rank but the laggard waited: {:?}", states);
    for ws in &coll {
        assert!(ws.rank < 3);
        let expect = 3.0 - ws.rank as f64;
        assert!(
            (ws.duration - expect).abs() < 1e-12,
            "rank {} waited {} != {}",
            ws.rank,
            ws.duration,
            expect
        );
    }
    // the critical path runs through the last entrant (rank 3)
    let cp = critical_path(&trace).unwrap();
    assert!(cp.segments.iter().any(|s| s.rank == 3));
}

#[test]
fn tiny_ring_capacity_drops_events_with_explicit_counter() {
    let cfg = WorldConfig::new(2, MachineModel::test_machine())
        .with_timeout(Duration::from_secs(20));
    let profiles = World::run(cfg, |rank| {
        let cali = Caliper::attach_cfg(
            rank,
            ChannelConfig::parse("comm-stats,trace.max-events-per-rank=8").unwrap(),
        );
        let world = rank.world();
        {
            let _main = cali.region("main");
            for i in 0..20 {
                if rank.rank == 0 {
                    rank.send(&[i as f64], 1, 0, &world).unwrap();
                } else {
                    let _ = rank.recv::<f64>(Some(0), 0, &world).unwrap();
                }
            }
        }
        cali.finish(rank)
    });
    let trace = RunTrace::new(
        profiles
            .into_iter()
            .filter_map(|mut p| p.trace.take())
            .collect(),
    );
    assert!(trace.dropped_events() > 0, "tiny ring must drop");
    for tr in &trace.ranks {
        assert!(tr.events.len() <= 8, "ring bounded at capacity");
        assert_eq!(tr.capacity, 8);
    }
    // the drop counter survives into the artifact header
    let text = write_jsonl(&trace);
    let first = text.lines().next().unwrap();
    assert!(
        first.contains(&format!("\"dropped_events\":{}", trace.dropped_events())),
        "header: {}",
        first
    );
}

#[test]
fn run_cell_stamps_trace_meta_and_artifact_roundtrips() {
    let spec = ExperimentSpec {
        app: AppKind::Amg2023,
        system: SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks: 8,
    };
    let out = run_cell_full(&spec, &traced_opts()).unwrap();
    let trace = out.trace.expect("trace present");
    assert_eq!(
        out.profile.meta.get("trace_events").map(String::as_str),
        Some(trace.n_events().to_string().as_str())
    );
    assert_eq!(
        out.profile.meta.get("trace_dropped").map(String::as_str),
        Some("0")
    );
    assert!(trace.n_events() > 0);
    // AMG's tioga halo crosses the 4 KiB eager threshold → rendezvous →
    // the run classifies real wait states.
    let n_late: usize = out
        .profile
        .meta
        .get("trace_late_senders")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let states = classify(&trace);
    assert_eq!(
        n_late,
        states.iter().filter(|s| s.kind == WaitKind::LateSender).count(),
        "meta count agrees with a fresh classification"
    );
    // artifact: lossless + byte-stable
    let text = write_jsonl(&trace);
    let back = read_jsonl(&text).expect("parses");
    assert_eq!(back, trace);
    assert_eq!(write_jsonl(&back), text);
    // a profile region carries the trace payload after the fold
    assert!(
        out.profile
            .regions
            .values()
            .any(|r| r.trace.map(|t| t.critpath > 0.0).unwrap_or(false)),
        "some region owns critical-path time"
    );
    // profile JSON roundtrip preserves the trace payload
    let j = out.profile.to_json();
    let rp2 = commscope::caliper::RunProfile::from_json(&j).unwrap();
    for (path, reg) in &out.profile.regions {
        assert_eq!(
            reg.trace, rp2.regions[path].trace,
            "trace payload of '{}' survives profile JSON",
            path
        );
    }
}

#[test]
fn traces_are_deterministic_across_runs() {
    let spec = ExperimentSpec {
        app: AppKind::Kripke,
        system: SystemId::Tioga,
        scaling: Scaling::Weak,
        nranks: 8,
    };
    let a = run_cell_full(&spec, &traced_opts()).unwrap();
    let b = run_cell_full(&spec, &traced_opts()).unwrap();
    assert_eq!(
        write_jsonl(a.trace.as_ref().unwrap()),
        write_jsonl(b.trace.as_ref().unwrap()),
        "identical cells must serialize byte-identical traces"
    );
    assert_eq!(
        a.profile.to_json().to_string_pretty(),
        b.profile.to_json().to_string_pretty()
    );
}
