//! Property-based tests over randomized configurations (a minimal
//! proptest-style harness: seeded generators, many cases, failing seeds
//! printed for reproduction — the offline crate set has no proptest).
//!
//! Invariants exercised:
//! - message/byte conservation for random apps × machines × topologies
//! - virtual-clock monotonicity and schedule independence (determinism)
//! - collective results equal a sequential oracle for random inputs
//! - cartesian topology round-trips and symmetry under random dims
//! - aggregation linearity: aggregate(profiles) totals = Σ per-rank

use commscope::caliper::aggregate::{aggregate, check_conservation};
use commscope::caliper::Caliper;
use commscope::mpisim::cart::CartComm;
use commscope::mpisim::collectives::ReduceOp;
use commscope::mpisim::{MachineModel, World, WorldConfig};
use commscope::util::rng::Rng;
use std::collections::BTreeMap;

/// Run `cases` randomized cases, printing the failing seed.
fn for_seeds(name: &str, cases: u64, f: impl Fn(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case * 0x9E3779B9);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{}' failed at seed {:#x}: {:?}", name, seed, e);
        }
    }
}

fn random_machine(rng: &mut Rng) -> MachineModel {
    let mut m = MachineModel::test_machine();
    m.ranks_per_node = *rng.choose(&[1usize, 2, 4, 8]);
    m.net.alpha_inter = rng.range_f64(0.5e-6, 5e-6);
    m.net.beta_inter = 1.0 / rng.range_f64(1e9, 50e9);
    m.net.nic_share = rng.range_f64(0.0, 10.0);
    m.net.contention_coeff = rng.range_f64(0.0, 0.5);
    m.compute.flops = rng.range_f64(1e9, 1e12);
    m
}

#[test]
fn prop_random_traffic_conserves_and_is_deterministic() {
    for_seeds("traffic_conservation", 8, |rng| {
        let n = *rng.choose(&[2usize, 3, 4, 6, 8]);
        let machine = random_machine(rng);
        let rounds = rng.range(1, 5) as usize;
        let msg_elems = rng.range(1, 2048) as usize;
        let seed = rng.next_u64();
        let run_once = || {
            let cfg = WorldConfig::new(n, machine.clone());
            let profiles = World::run(cfg, |rank| {
                let cali = Caliper::attach(rank);
                let world = rank.world();
                let mut local_rng = Rng::new(seed ^ rank.rank as u64);
                {
                    let _main = cali.region("main");
                    for round in 0..rounds {
                        {
                            let _ring = cali.comm_region("ring");
                            // deterministic ring with randomized payload sizes
                            let next = (rank.rank + 1) % n;
                            let prev = (rank.rank + n - 1) % n;
                            let len = 1 + (local_rng.next_u64() as usize) % msg_elems;
                            // IMPORTANT: receiver can't know len; it just receives
                            // requests above the eager threshold stay
                            // pending; a ring never send-waits, so hold
                            // the handle through the matching receive
                            let sreq = rank
                                .isend(&vec![0.5f64; len], next, round as i32, &world)
                                .unwrap();
                            let _ = rank.recv::<f64>(Some(prev), round as i32, &world).unwrap();
                            rank.wait_send(sreq).unwrap();
                        }
                        rank.compute(local_rng.range_f64(1e3, 1e6), 1e3);
                    }
                }
                (cali.finish(rank), rank.now())
            });
            profiles
        };
        let a = run_once();
        let b = run_once();
        let pa: Vec<_> = a.iter().map(|(p, _)| p.clone()).collect();
        check_conservation(&pa).unwrap();
        for ((p1, t1), (p2, t2)) in a.iter().zip(&b) {
            assert_eq!(t1.to_bits(), t2.to_bits(), "virtual time must be deterministic");
            assert_eq!(
                p1.to_json().to_string_compact(),
                p2.to_json().to_string_compact(),
                "profiles must be deterministic"
            );
        }
        // clocks never go backwards: end time >= 0 and regions non-negative
        for (p, t) in &a {
            assert!(*t >= 0.0);
            for s in p.regions.values() {
                assert!(s.time_incl >= -1e-15);
            }
        }
    });
}

#[test]
fn prop_collectives_match_sequential_oracle() {
    for_seeds("collective_oracle", 8, |rng| {
        let n = rng.range(2, 12) as usize;
        let lanes = rng.range(1, 16) as usize;
        let machine = random_machine(rng);
        let seed = rng.next_u64();
        let op = *rng.choose(&[ReduceOp::Sum, ReduceOp::Min, ReduceOp::Max]);
        // oracle inputs
        let inputs: Vec<Vec<f64>> = (0..n)
            .map(|r| {
                let mut rr = Rng::new(seed ^ r as u64);
                (0..lanes).map(|_| rr.range_f64(-100.0, 100.0)).collect()
            })
            .collect();
        let mut expect = vec![op.identity_f64(); lanes];
        for row in &inputs {
            for (e, v) in expect.iter_mut().zip(row) {
                *e = op.apply_f64(*e, *v);
            }
        }
        let cfg = WorldConfig::new(n, machine);
        let results = World::run(cfg, |rank| {
            let world = rank.world();
            let mut rr = Rng::new(seed ^ rank.rank as u64);
            let mine: Vec<f64> = (0..lanes).map(|_| rr.range_f64(-100.0, 100.0)).collect();
            rank.allreduce_f64(&mine, op, &world).unwrap()
        });
        for r in results {
            for (got, want) in r.iter().zip(&expect) {
                assert!(
                    (got - want).abs() < 1e-9 * want.abs().max(1.0),
                    "allreduce {} vs {}",
                    got,
                    want
                );
            }
        }
    });
}

#[test]
fn prop_allgather_preserves_every_contribution() {
    for_seeds("allgather", 6, |rng| {
        let n = rng.range(2, 10) as usize;
        let machine = random_machine(rng);
        let cfg = WorldConfig::new(n, machine);
        let results = World::run(cfg, |rank| {
            let world = rank.world();
            let mine: Vec<u32> = (0..rank.rank as u32 % 7)
                .map(|i| rank.rank as u32 * 100 + i)
                .collect();
            rank.allgatherv(&mine, &world).unwrap()
        });
        for r in &results {
            assert_eq!(r.len(), n);
            for (src, part) in r.iter().enumerate() {
                assert_eq!(part.len(), src % 7);
                for (i, v) in part.iter().enumerate() {
                    assert_eq!(*v, src as u32 * 100 + i as u32);
                }
            }
        }
    });
}

#[test]
fn prop_cart_roundtrip_and_symmetry() {
    for_seeds("cart", 32, |rng| {
        let dims = [
            rng.range(1, 6) as usize,
            rng.range(1, 6) as usize,
            rng.range(1, 6) as usize,
        ];
        let size: usize = dims.iter().product();
        for r in 0..size {
            let c = CartComm::rank_to_coords(r, &dims);
            assert_eq!(CartComm::coords_to_rank(&c, &dims), r);
        }
        // face-neighbor symmetry
        let carts: Vec<CartComm> = (0..size)
            .map(|r| {
                CartComm::new(
                    commscope::mpisim::Comm::world(r, size),
                    &dims,
                    &[false, false, false],
                )
                .unwrap()
            })
            .collect();
        for (r, cart) in carts.iter().enumerate() {
            for nbr in cart.face_neighbors().into_iter().flatten() {
                assert!(
                    carts[nbr].face_neighbors().into_iter().flatten().any(|b| b == r),
                    "asymmetric neighbors {} {}",
                    r,
                    nbr
                );
            }
        }
        // dims_create covers the size
        let d = CartComm::dims_create(size, 3);
        assert_eq!(d.iter().product::<usize>(), size);
    });
}

#[test]
fn prop_aggregation_totals_are_sums() {
    for_seeds("aggregation_linearity", 16, |rng| {
        use commscope::caliper::profile::{RankProfile, RegionStats};
        let nranks = rng.range(1, 20) as usize;
        let mut profiles = Vec::new();
        let mut want_sends = 0u64;
        let mut want_bytes = 0u64;
        for r in 0..nranks {
            let mut p = RankProfile {
                rank: r,
                ..Default::default()
            };
            let mut s = RegionStats {
                is_comm_region: true,
                visits: 1,
                ..Default::default()
            };
            let n_msg = rng.range(0, 50);
            for _ in 0..n_msg {
                let bytes = rng.range(1, 1 << 20);
                s.record_send((r + 1) % nranks.max(2), bytes);
                want_sends += 1;
                want_bytes += bytes;
            }
            p.regions.insert("x".to_string(), s);
            profiles.push(p);
        }
        let run = aggregate(BTreeMap::new(), &profiles);
        let reg = &run.regions["x"];
        assert_eq!(reg.sends.total() as u64, want_sends);
        assert_eq!(reg.bytes_sent.total() as u64, want_bytes);
        assert_eq!(reg.participants as usize, nranks);
        // min ≤ avg ≤ max
        assert!(reg.sends.min() <= reg.sends.avg() + 1e-9);
        assert!(reg.sends.avg() <= reg.sends.max() + 1e-9);
    });
}

#[test]
fn prop_transfer_time_monotone() {
    for_seeds("netmodel_monotone", 32, |rng| {
        let m = random_machine(rng);
        let total = 64;
        let b1 = rng.range(1, 1 << 22) as usize;
        let b2 = b1 + rng.range(1, 1 << 20) as usize;
        // monotone in bytes, for both link classes
        assert!(m.transfer_time(b2, 0, 1, total) >= m.transfer_time(b1, 0, 1, total));
        let far = m.ranks_per_node; // first off-node rank
        if far < total {
            assert!(m.transfer_time(b2, 0, far, total) >= m.transfer_time(b1, 0, far, total));
            // inter-node never faster than intra-node for same bytes
            assert!(
                m.transfer_time(b1, 0, far, total) >= m.transfer_time(b1, 0, 1, total) - 1e-15
            );
        }
    });
}
