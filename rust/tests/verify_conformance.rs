//! The MPI conformance analyzer end to end: one constructed erroneous
//! program per diagnostic code (asserting the exact code, rank, and
//! enclosing region path), the waitany-on-all-inactive bugfix on both
//! engines, and verify-clean assertions for every shipped app.
//!
//! V002 (double wait) and V008 (byte conservation) cannot be produced
//! through the safe API — a waited request degrades to `Request::Null`
//! (whose re-wait is V003), and the transport records both sides of a
//! message from the same envelope — so those two feed the verifier
//! synthesized streams/records, which is exactly the layer `check_run`
//! consumes.

use std::time::Duration;

use commscope::benchpark::runner::{run_cell_full, RunOptions};
use commscope::benchpark::{AppKind, ExperimentSpec, Scaling, SystemId};
use commscope::caliper::Caliper;
use commscope::mpisim::collectives::ReduceOp;
use commscope::mpisim::verify::{check_run, RecvRec, SendRec};
use commscope::mpisim::{
    Engine, MachineModel, MpiError, MpiEvent, Rank, RankVerify, Request, RunVerify,
    StreamVerifier, World, WorldConfig,
};

fn cfg(n: usize) -> WorldConfig {
    WorldConfig::new(n, MachineModel::test_machine()).with_timeout(Duration::from_secs(20))
}

/// Run `f` on `n` ranks with the `verify` channel attached, inside a
/// `main` region, and return the cross-rank verification result.
fn run_verified<F>(n: usize, f: F) -> RunVerify
where
    F: Fn(&mut Rank, &Caliper) + Sync,
{
    let profiles = World::run(cfg(n), |rank| {
        let cali = Caliper::attach_with(rank, "verify").unwrap();
        {
            let _main = cali.region("main");
            f(rank, &cali);
        }
        cali.finish(rank)
    });
    let rvs: Vec<RankVerify> = profiles
        .into_iter()
        .filter_map(|mut p| p.verify.take())
        .collect();
    assert_eq!(rvs.len(), n, "every rank carries a verify payload");
    check_run(&rvs)
}

#[test]
fn v001_leaked_request_attributed_to_post_site() {
    let rv = run_verified(2, |rank, cali| {
        let world = rank.world();
        let _halo = cali.comm_region("halo");
        if rank.rank == 0 {
            // posted, never waited, never matched — leaks at finish
            let _req = rank.irecv(Some(1), 5, &world).unwrap();
        }
    });
    assert_eq!(rv.diagnostics.len(), 1, "{}", rv.render());
    let d = &rv.diagnostics[0];
    assert_eq!(d.code, "V001");
    assert_eq!(d.rank, 0);
    assert_eq!(d.region, "main/halo");
}

#[test]
fn v002_double_wait_via_synthesized_stream() {
    let mut v = StreamVerifier::new();
    v.on_event(
        &MpiEvent::VerifySendPost {
            vid: 1,
            dst: 1,
            tag: 0,
            ctx: 0,
            bytes: 8,
            t: 0.0,
        },
        "main/halo",
    );
    v.on_event(&MpiEvent::VerifySendDone { vid: 1, t: 1.0 }, "main/halo");
    v.on_event(&MpiEvent::VerifySendDone { vid: 1, t: 2.0 }, "main/halo");
    let rv = check_run(&[v.finish(3)]);
    assert_eq!(rv.diagnostics.len(), 1, "{}", rv.render());
    let d = &rv.diagnostics[0];
    assert_eq!(d.code, "V002");
    assert_eq!(d.rank, 3);
    assert_eq!(d.region, "main/halo");
}

#[test]
fn v003_wait_on_inactive_reported_with_region() {
    let rv = run_verified(1, |rank, cali| {
        let _w = cali.comm_region("drain");
        let mut reqs = vec![Request::null(), Request::null()];
        let err = rank.waitany::<u8>(&mut reqs).unwrap_err();
        assert!(
            matches!(err, MpiError::WaitOnInactive { rank: 0, n_reqs: 2 }),
            "{err:?}"
        );
    });
    assert_eq!(rv.diagnostics.len(), 1, "{}", rv.render());
    let d = &rv.diagnostics[0];
    assert_eq!(d.code, "V003");
    assert_eq!(d.rank, 0);
    assert_eq!(d.region, "main/drain");
}

/// The bugfix itself, independent of the analyzer: an all-`MPI_REQUEST_NULL`
/// waitany must return `WaitOnInactive` instead of parking until the
/// wall-clock guard (threaded) or a phantom deadlock (event engine).
#[test]
fn waitany_all_inactive_errors_on_both_engines() {
    for engine in [Engine::Threaded, Engine::event()] {
        World::run(cfg(1).with_engine(engine), |rank| {
            let mut reqs = vec![Request::null()];
            let err = rank.waitany::<u8>(&mut reqs).unwrap_err();
            assert!(
                matches!(err, MpiError::WaitOnInactive { rank: 0, n_reqs: 1 }),
                "engine {}: {err:?}",
                engine.name()
            );
            // The rank is still usable after the error.
            let mut live = vec![Request::null()];
            live.push(Request::null());
            assert!(rank.waitany::<u8>(&mut live).is_err());
        });
    }
}

#[test]
fn v004_tag_out_of_range_on_both_sides() {
    let rv = run_verified(2, |rank, cali| {
        let world = rank.world();
        let _t = cali.comm_region("tags");
        if rank.rank == 0 {
            rank.send(&[1.0f64], 1, 40_000, &world).unwrap();
        } else {
            rank.recv::<f64>(Some(0), 40_000, &world).unwrap();
        }
    });
    // The bad tag is diagnosed at the send post AND the receive post.
    assert_eq!(rv.diagnostics.len(), 2, "{}", rv.render());
    for (d, rank) in rv.diagnostics.iter().zip([0usize, 1]) {
        assert_eq!(d.code, "V004");
        assert_eq!(d.rank, rank);
        assert_eq!(d.region, "main/tags");
    }
}

#[test]
fn v005_truncation_on_the_receiver() {
    let rv = run_verified(2, |rank, cali| {
        let world = rank.world();
        let _x = cali.comm_region("xfer");
        if rank.rank == 0 {
            // 12 bytes into an f64 receive: 12 % 8 != 0
            rank.send(&[0u8; 12], 1, 3, &world).unwrap();
        } else {
            // The decode fails with PayloadSizeMismatch — the diagnostic
            // is recorded before the error surfaces.
            assert!(rank.recv::<f64>(Some(0), 3, &world).is_err());
        }
    });
    assert_eq!(rv.diagnostics.len(), 1, "{}", rv.render());
    let d = &rv.diagnostics[0];
    assert_eq!(d.code, "V005");
    assert_eq!(d.rank, 1);
    assert_eq!(d.region, "main/xfer");
}

#[test]
fn v006_unmatched_send_attributed_to_sender() {
    let rv = run_verified(2, |rank, cali| {
        let world = rank.world();
        let _s = cali.comm_region("sends");
        if rank.rank == 0 {
            // eager: completes locally; the receiver never posts
            rank.send(&[7u8; 8], 1, 9, &world).unwrap();
        }
    });
    assert_eq!(rv.diagnostics.len(), 1, "{}", rv.render());
    let d = &rv.diagnostics[0];
    assert_eq!(d.code, "V006");
    assert_eq!(d.rank, 0);
    assert_eq!(d.region, "main/sends");
}

#[test]
fn v007_collective_op_divergence_names_the_exact_call() {
    // Same kind, same sequence slot, different reduction operator: the
    // collective board is blind to this (it matches kind names only), so
    // the run completes — only the analyzer catches it.
    let rv = run_verified(2, |rank, cali| {
        let world = rank.world();
        let _r = cali.comm_region("reduce");
        let op = if rank.rank == 0 {
            ReduceOp::Min
        } else {
            ReduceOp::Max
        };
        rank.allreduce_f64(&[1.0], op, &world).unwrap();
    });
    assert_eq!(rv.diagnostics.len(), 1, "{}", rv.render());
    let d = &rv.diagnostics[0];
    assert_eq!(d.code, "V007");
    assert_eq!(d.rank, 1, "divergence is blamed on the non-reference rank");
    assert_eq!(d.region, "main/reduce");
    assert!(d.message.contains("call #"), "{}", d.message);
    assert!(
        d.message.contains("op=min") && d.message.contains("op=max"),
        "{}",
        d.message
    );
}

#[test]
fn v008_byte_conservation_via_synthesized_records() {
    // Count-matched but byte-mismatched send/recv pair: impossible through
    // the real transport (both sides record the same envelope), so feed
    // the cross-rank checker records directly.
    let a = RankVerify {
        rank: 0,
        sends: vec![SendRec {
            vid: 1,
            dst: 1,
            tag: 0,
            ctx: 0,
            bytes: 100,
            t: 0.5,
            region: "main".into(),
        }],
        ..Default::default()
    };
    let b = RankVerify {
        rank: 1,
        recvs: vec![RecvRec {
            vid: 1,
            src: 0,
            tag: 0,
            ctx: 0,
            bytes: 60,
            t: 0.5,
            region: "main".into(),
        }],
        ..Default::default()
    };
    let rv = check_run(&[a, b]);
    assert_eq!(rv.diagnostics.len(), 1, "{}", rv.render());
    let d = &rv.diagnostics[0];
    assert_eq!(d.code, "V008");
    assert_eq!(d.rank, 0);
    assert!(
        d.message.contains("100") && d.message.contains("60"),
        "{}",
        d.message
    );
}

/// Every shipped app, on its smallest paper cell, is verify-clean on both
/// engines — the acceptance bar for `repro verify` and the CI verify job.
/// Laghos has no Tioga cells in the paper, so its smallest cell is
/// dane/112; the grid apps use tioga/8.
#[test]
fn all_shipped_apps_are_verify_clean_on_both_engines() {
    let cells = [
        (AppKind::Amg2023, SystemId::Tioga, 8, Scaling::Weak),
        (AppKind::Kripke, SystemId::Tioga, 8, Scaling::Weak),
        (AppKind::Zmodel, SystemId::Tioga, 8, Scaling::Weak),
        (AppKind::Laghos, SystemId::Dane, 112, Scaling::Strong),
    ];
    for engine in [Engine::Threaded, Engine::event()] {
        for &(app, system, nranks, scaling) in &cells {
            let spec = ExperimentSpec {
                app,
                system,
                scaling,
                nranks,
            };
            let opts = RunOptions {
                iter_shrink: 10,
                size_shrink: 8,
                verify: true, // strict: any diagnostic fails the cell
                engine,
                ..Default::default()
            };
            let out = run_cell_full(&spec, &opts)
                .unwrap_or_else(|e| panic!("{} [{}]: {e:#}", spec.id(), engine.name()));
            let rv = out
                .profile
                .verify
                .as_ref()
                .unwrap_or_else(|| panic!("{}: verify payload missing", spec.id()));
            assert!(rv.clean(), "{} [{}]: {}", spec.id(), engine.name(), rv.render());
            assert_eq!(rv.ranks, nranks);
            assert!(rv.sends > 0 && rv.colls > 0, "{}", rv.render());
        }
    }
}
