//! Sub-communicator semantics: `comm_split` determinism, message and
//! collective context isolation between sibling splits, alltoallv
//! round-trips on subgroups, and the span-based collective cost model
//! (a single-node sub-communicator must pay intra-node prices).

use std::collections::BTreeMap;
use std::time::Duration;

use commscope::caliper::aggregate::{aggregate, check_matrix_conservation};
use commscope::caliper::Caliper;
use commscope::mpisim::collectives::ReduceOp;
use commscope::mpisim::netmodel::CollClass;
use commscope::mpisim::{MachineModel, World, WorldConfig};

fn cfg(n: usize) -> WorldConfig {
    WorldConfig::new(n, MachineModel::test_machine()).with_timeout(Duration::from_secs(20))
}

#[test]
fn comm_split_is_deterministic_and_key_ordered() {
    let run = || {
        World::run(cfg(8), |rank| {
            let world = rank.world();
            // reversed keys: communicator rank order must invert world order
            let sub = rank
                .comm_split(&world, (rank.rank % 2) as u64, (8 - rank.rank) as u64)
                .unwrap();
            (sub.ctx, sub.rank, sub.ranks.clone())
        })
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "split must be bit-reproducible");
    // even group, ordered by descending world rank via the key
    let (ctx0, _, members0) = &a[0];
    assert_eq!(members0, &vec![6, 4, 2, 0]);
    assert_eq!(&a[6].2, members0, "same color ⇒ same member list");
    assert_eq!(a[0].1, 3, "world rank 0 has the largest key ⇒ last");
    // sibling splits get distinct contexts, both distinct from world's 0
    let (ctx1, _, _) = &a[1];
    assert_ne!(ctx0, ctx1);
    assert_ne!(*ctx0, 0);
    assert_ne!(*ctx1, 0);
}

#[test]
fn sibling_splits_isolate_p2p_and_collectives() {
    // Evens and odds each run the same program — same tags, same
    // collective sequence — on their own split. Nothing may cross.
    let res = World::run(cfg(6), |rank| {
        let world = rank.world();
        let color = (rank.rank % 2) as u64;
        let sub = rank.comm_split(&world, color, rank.rank as u64).unwrap();
        // ring send on the sub-communicator, tag 7 in both siblings
        let next = (sub.rank + 1) % sub.size();
        let prev = (sub.rank + sub.size() - 1) % sub.size();
        rank.send(&[rank.rank as f64], next, 7, &sub).unwrap();
        let (got, st) = rank.recv::<f64>(Some(prev), 7, &sub).unwrap();
        // the payload must come from my sibling group, not the other one
        assert_eq!(st.src, sub.world_rank(prev));
        assert_eq!(got[0] as usize % 2, rank.rank % 2, "crossed the split");
        // collectives sequence independently per context
        let s = rank
            .allreduce_f64(&[rank.rank as f64], ReduceOp::Sum, &sub)
            .unwrap();
        // and a world-wide collective still works afterwards
        let w = rank
            .allreduce_f64(&[1.0], ReduceOp::Sum, &world)
            .unwrap();
        (got[0], s[0], w[0])
    });
    for (r, (got, sub_sum, world_sum)) in res.iter().enumerate() {
        assert_eq!(*got as usize % 2, r % 2);
        let expect: f64 = if r % 2 == 0 { 0.0 + 2.0 + 4.0 } else { 1.0 + 3.0 + 5.0 };
        assert_eq!(*sub_sum, expect);
        assert_eq!(*world_sum, 6.0);
    }
}

#[test]
fn alltoallv_roundtrip_on_subgroup() {
    // Split 8 ranks into two halves; alltoallv runs inside each half with
    // communicator-local indices and distinct payloads.
    let res = World::run(cfg(8), |rank| {
        let world = rank.world();
        let color = (rank.rank / 4) as u64;
        let sub = rank.comm_split(&world, color, rank.rank as u64).unwrap();
        let p = sub.size();
        let parts: Vec<Vec<u32>> = (0..p)
            .map(|d| vec![(rank.rank * 10 + sub.world_rank(d)) as u32; d + 1])
            .collect();
        let out = rank.alltoallv(&parts, &sub).unwrap();
        (sub.rank, out)
    });
    for (world_rank, (sub_rank, out)) in res.iter().enumerate() {
        let base = (world_rank / 4) * 4;
        assert_eq!(out.len(), 4);
        for (src, part) in out.iter().enumerate() {
            // source sub-rank src = world rank base+src (keys ascending)
            assert_eq!(part.len(), sub_rank + 1, "count from {} to {}", src, world_rank);
            let expect = ((base + src) * 10 + world_rank) as u32;
            assert!(part.iter().all(|v| *v == expect), "payload crossed groups");
        }
    }
}

#[test]
fn subgroup_alltoallv_matrix_is_block_local_and_conserved() {
    // With the comm-matrix channel on, the two halves' alltoallv traffic
    // must form two dense 4×4 blocks and never a cross-block cell.
    let n = 8;
    let profiles = World::run(cfg(n), |rank| {
        let cali = Caliper::attach_with(rank, "comm-stats,comm-matrix").unwrap();
        let world = rank.world();
        let color = (rank.rank / 4) as u64;
        let sub = rank.comm_split(&world, color, rank.rank as u64).unwrap();
        {
            let _x = cali.comm_region("block_exchange");
            let parts: Vec<Vec<f64>> = (0..sub.size()).map(|d| vec![1.0; d + 2]).collect();
            rank.alltoallv(&parts, &sub).unwrap();
        }
        cali.finish(rank)
    });
    let run = aggregate(BTreeMap::new(), &profiles);
    let m = run.regions["block_exchange"].comm_matrix.as_ref().unwrap();
    check_matrix_conservation(m).unwrap();
    assert_eq!(m.sent.len(), 2 * 4 * 3, "two dense 4-rank blocks");
    for ((s, d), _) in &m.sent {
        assert_eq!(s / 4, d / 4, "cell ({}, {}) crossed the split", s, d);
    }
}

#[test]
fn span_model_prices_subgroups_by_their_nodes() {
    // Direct model-level acceptance: on the 4-ranks/node test machine a
    // 4-rank single-node group costs intra-node α/β, strictly under the
    // same collective on 4 ranks spread over 4 nodes — for every class.
    let m = MachineModel::test_machine();
    let local = m.group_span(&[4, 5, 6, 7]); // node 1, all four slots
    let spread = m.group_span(&[0, 4, 8, 12]);
    assert_eq!(local.nodes, 1);
    assert_eq!(spread.nodes, 4);
    for class in [
        CollClass::Barrier,
        CollClass::Bcast,
        CollClass::Reduce,
        CollClass::Allreduce,
        CollClass::Allgather,
        CollClass::Alltoall,
    ] {
        let t_local = m.collective_time_span(class, 8192, &local);
        let t_spread = m.collective_time_span(class, 8192, &spread);
        assert!(
            t_local < t_spread,
            "{:?}: local {} vs spread {}",
            class,
            t_local,
            t_spread
        );
    }
}

#[test]
fn virtual_time_cheaper_on_node_local_subgroup_end_to_end() {
    // End-to-end: the same allreduce program on a node-confined split
    // finishes earlier (virtual time) than on a node-spanning split of
    // the same size, inside one world.
    let times = World::run(cfg(16), |rank| {
        let world = rank.world();
        // node-local groups: color = node index (4 ranks/node)
        let local = rank
            .comm_split(&world, (rank.rank / 4) as u64, rank.rank as u64)
            .unwrap();
        // spanning groups: color = slot index → 4 ranks on 4 nodes
        let spanning = rank
            .comm_split(&world, (rank.rank % 4) as u64, rank.rank as u64)
            .unwrap();
        let t0 = rank.now();
        for _ in 0..10 {
            rank.allreduce_f64(&[1.0], ReduceOp::Sum, &local).unwrap();
        }
        let t_local = rank.now() - t0;
        let t1 = rank.now();
        for _ in 0..10 {
            rank.allreduce_f64(&[1.0], ReduceOp::Sum, &spanning).unwrap();
        }
        (t_local, rank.now() - t1)
    });
    for (r, (t_local, t_spanning)) in times.iter().enumerate() {
        assert!(
            t_local < t_spanning,
            "rank {}: node-local {} vs spanning {}",
            r,
            t_local,
            t_spanning
        );
    }
}
