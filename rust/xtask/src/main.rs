//! `cargo xtask lint` — run the determinism-contract lint over `rust/src`.
//!
//! Exit status: 0 clean, 1 findings, 2 usage error.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("lint") => {
            let root = args
                .get(1)
                .map(PathBuf::from)
                .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../src"));
            lint(&root)
        }
        _ => {
            eprintln!("usage: cargo xtask lint [SRC_ROOT]");
            eprintln!();
            eprintln!("Runs the determinism-contract lint (docs/DETERMINISM.md) over the");
            eprintln!("simulator sources. Rules: {}", xtask::RULES.join(", "));
            ExitCode::from(2)
        }
    }
}

fn lint(root: &std::path::Path) -> ExitCode {
    let findings = match xtask::lint_tree(root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("xtask lint: cannot read {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!(
            "determinism lint: clean ({} rules active over {})",
            xtask::RULES.len(),
            root.display()
        );
        ExitCode::SUCCESS
    } else {
        println!("determinism lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}
