//! The determinism-contract lint (`cargo xtask lint`).
//!
//! The simulator's headline guarantee — byte-identical artifacts for the
//! same cell across engines, worker counts, and repeated runs — is easy to
//! break with one innocent-looking line: an `Instant::now()` folded into
//! virtual time, a `HashMap` iterated into a report, a hand-rolled
//! `Condvar` wait with a lost-wakeup window. This lint makes those
//! regressions mechanical to catch. It is a *lexical* scanner (strings and
//! comments are masked, `#[cfg(test)]` items are skipped, token matches are
//! word-bounded) rather than a full parser, so it has zero dependencies and
//! runs on the offline vendored toolchain.
//!
//! Rules (see `docs/DETERMINISM.md` for the invariant each one guards):
//!
//! | id | scope | bans |
//! |----|-------|------|
//! | `wall-clock` | `mpisim/`, `trace/`, `caliper/` | `Instant`, `SystemTime`, `thread::sleep` |
//! | `hash-iter-artifact` | `caliper/`, `trace/`, `thicket/`, `coordinator/`, `benchpark/`, `store/`, `serve/` | `HashMap`, `HashSet` |
//! | `raw-sync` | all of `src/` except `util/sync.rs` | `std::sync::*`, `loom::*` |
//! | `park-protocol` | `mpisim/` | `thread::sleep`, `yield_now`, `spin_loop` |
//! | `unbounded-channel` | all of `src/` except `util/sync.rs` | `mpsc::channel` |
//! | `panic-in-drop` | all of `src/` | `panic!`/`unwrap(`/`expect(`/`assert…!` inside `fn drop` of an `impl Drop` |
//! | `bare-allow` | all of `src/` | `lint:allow(rule)` without a `-- rationale` |
//! | `comm-region` | `apps/` | MPI call sites lexically outside a `region`/`comm_region` guard scope |
//! | `halo-order` | `apps/` | `.irecv(` after an unretired `.isend(` in the same scope (post receives first) |
//!
//! A violation that is genuinely intended (e.g. a lookup-only intern table)
//! is suppressed with a comment on the same line or the comment block
//! immediately above it:
//!
//! ```text
//! // lint:allow(hash-iter-artifact) -- lookup-only intern table.
//! path_ids: HashMap<String, u32>,
//! ```
//!
//! Every suppression must carry a rationale after `--`; a bare
//! `lint:allow(rule)` still suppresses (so an un-annotated allow cannot
//! hide a second finding under itself) but is reported as `bare-allow` at
//! the directive line. The directive is scoped to one following code line,
//! so it cannot rot into a file-wide opt-out.

use std::fmt;
use std::path::Path;

/// One lint violation, formatted as `file:line: [rule] message — fix: …`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
    pub fix: &'static str,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {} — fix: {}",
            self.file, self.line, self.rule, self.message, self.fix
        )
    }
}

/// The rule identifiers, in reporting order.
pub const RULES: [&str; 9] = [
    "wall-clock",
    "hash-iter-artifact",
    "raw-sync",
    "park-protocol",
    "unbounded-channel",
    "panic-in-drop",
    "bare-allow",
    "comm-region",
    "halo-order",
];

// ---------------------------------------------------------------------------
// Source masking
// ---------------------------------------------------------------------------

/// Per-line scan state derived from one pass over the raw text.
struct Masked {
    /// Source with comment and string-literal *contents* replaced by
    /// spaces; newlines and code structure (braces, `;`) preserved.
    code: String,
    /// Comment text gathered per line (0-based), for directive extraction.
    comments: Vec<String>,
}

/// Mask comments and string/char literals so token scans can't be fooled
/// by text. Handles line + nested block comments, plain/byte/raw strings,
/// and distinguishes char literals from lifetimes.
fn mask(text: &str) -> Masked {
    let bytes: Vec<char> = text.chars().collect();
    let mut code = String::with_capacity(text.len());
    let mut comments: Vec<String> = vec![String::new()];
    let mut line = 0usize;
    let mut i = 0usize;

    let push_masked = |code: &mut String, c: char| {
        code.push(if c == '\n' { '\n' } else { ' ' });
    };

    while i < bytes.len() {
        let c = bytes[i];
        if c == '\n' {
            code.push('\n');
            comments.push(String::new());
            line += 1;
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && bytes.get(i + 1) == Some(&'/') {
            while i < bytes.len() && bytes[i] != '\n' {
                comments[line].push(bytes[i]);
                code.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment (nesting, possibly multi-line).
        if c == '/' && bytes.get(i + 1) == Some(&'*') {
            let mut depth = 0usize;
            while i < bytes.len() {
                if bytes[i] == '/' && bytes.get(i + 1) == Some(&'*') {
                    depth += 1;
                    comments[line].push_str("/*");
                    code.push_str("  ");
                    i += 2;
                } else if bytes[i] == '*' && bytes.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    comments[line].push_str("*/");
                    code.push_str("  ");
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    if bytes[i] == '\n' {
                        code.push('\n');
                        comments.push(String::new());
                        line += 1;
                    } else {
                        comments[line].push(bytes[i]);
                        code.push(' ');
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw string: r"…", r#"…"#, br"…" etc.
        if (c == 'r' || (c == 'b' && bytes.get(i + 1) == Some(&'r')))
            && !prev_is_ident(&bytes, i)
        {
            let start = if c == 'b' { i + 1 } else { i };
            let mut j = start + 1;
            let mut hashes = 0usize;
            while bytes.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if bytes.get(j) == Some(&'"') {
                // Emit the opener masked, then consume to the closer.
                while i <= j {
                    push_masked(&mut code, bytes[i]);
                    if bytes[i] == '\n' {
                        comments.push(String::new());
                        line += 1;
                    }
                    i += 1;
                }
                loop {
                    if i >= bytes.len() {
                        break;
                    }
                    if bytes[i] == '"' {
                        let mut k = 0usize;
                        while k < hashes && bytes.get(i + 1 + k) == Some(&'#') {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                code.push(' ');
                                i += 1;
                            }
                            break;
                        }
                    }
                    if bytes[i] == '\n' {
                        code.push('\n');
                        comments.push(String::new());
                        line += 1;
                    } else {
                        code.push(' ');
                    }
                    i += 1;
                }
                continue;
            }
        }
        // Plain / byte string.
        if c == '"' || (c == 'b' && bytes.get(i + 1) == Some(&'"') && !prev_is_ident(&bytes, i)) {
            if c == 'b' {
                code.push(' ');
                i += 1;
            }
            code.push(' ');
            i += 1; // opening quote
            while i < bytes.len() {
                if bytes[i] == '\\' {
                    // An escaped newline (line-continuation) must still
                    // advance the line bookkeeping or every later finding
                    // in the file is reported one line early.
                    if bytes.get(i + 1) == Some(&'\n') {
                        code.push(' ');
                        code.push('\n');
                        comments.push(String::new());
                        line += 1;
                    } else {
                        code.push_str("  ");
                    }
                    i += 2;
                    continue;
                }
                if bytes[i] == '"' {
                    code.push(' ');
                    i += 1;
                    break;
                }
                if bytes[i] == '\n' {
                    code.push('\n');
                    comments.push(String::new());
                    line += 1;
                } else {
                    code.push(' ');
                }
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            let is_char = match bytes.get(i + 1) {
                Some('\\') => true,
                Some(_) => bytes.get(i + 2) == Some(&'\''),
                None => false,
            };
            if is_char {
                code.push(' ');
                i += 1;
                while i < bytes.len() && bytes[i] != '\'' {
                    if bytes[i] == '\\' {
                        code.push(' ');
                        i += 1;
                    }
                    code.push(' ');
                    i += 1;
                }
                code.push(' ');
                i += 1;
                continue;
            }
        }
        code.push(c);
        i += 1;
    }
    Masked { code, comments }
}

fn prev_is_ident(bytes: &[char], i: usize) -> bool {
    i > 0 && (bytes[i - 1].is_alphanumeric() || bytes[i - 1] == '_')
}

// ---------------------------------------------------------------------------
// Directives and test-item skipping
// ---------------------------------------------------------------------------

/// One parsed `lint:allow(rule)` directive.
struct Allow {
    /// 0-based code line the directive covers.
    target: usize,
    /// 0-based line the directive itself sits on (for `bare-allow`).
    directive_line: usize,
    rule: String,
    /// `true` when a non-empty `-- rationale` follows the closing paren.
    rationale: bool,
}

/// `lint:allow(rule) -- rationale` directives resolved to the code lines
/// they cover. A directive covers its own line (trailing-comment form)
/// and, when the directive line has no code, the first following line that
/// does. A directive without a rationale still suppresses — and is itself
/// reported by the `bare-allow` rule.
fn allowed_lines(masked: &Masked) -> Vec<Allow> {
    let code_lines: Vec<&str> = masked.code.lines().collect();
    let has_code = |idx: usize| {
        code_lines
            .get(idx)
            .map(|l| !l.trim().is_empty())
            .unwrap_or(false)
    };
    let mut out = Vec::new();
    for (idx, comment) in masked.comments.iter().enumerate() {
        let mut rest = comment.as_str();
        while let Some(pos) = rest.find("lint:allow(") {
            rest = &rest[pos + "lint:allow(".len()..];
            if let Some(end) = rest.find(')') {
                let rule = rest[..end].trim().to_string();
                let after = rest[end + 1..].trim_start();
                let rationale = after
                    .strip_prefix("--")
                    .map(|r| !r.trim().is_empty())
                    .unwrap_or(false);
                let mut target = idx;
                if !has_code(idx) {
                    // Walk down past further comment/blank lines to the
                    // first code line; that single line is covered.
                    let mut j = idx + 1;
                    while j < code_lines.len() && !has_code(j) {
                        j += 1;
                    }
                    target = j;
                }
                out.push(Allow {
                    target,
                    directive_line: idx,
                    rule,
                    rationale,
                });
                rest = &rest[end..];
            } else {
                break;
            }
        }
    }
    out
}

/// Mark lines belonging to `#[cfg(test)]` / `#[cfg(all(test, …))]` items
/// (and the attribute line itself) so test-only code is exempt. Handles
/// both `mod … { … }` blocks and single-line items ending in `;`.
fn test_skip_lines(code: &str) -> Vec<bool> {
    let n_lines = code.lines().count();
    let mut skip = vec![false; n_lines];
    let chars: Vec<char> = code.chars().collect();
    let line_of = build_line_index(&chars);

    let mut i = 0usize;
    while let Some(pos) = code[i..].find("#[cfg(") {
        let start = i + pos;
        // The attribute runs to its matching `]`.
        let attr_end = match find_matching(&chars, start + 1, '[', ']') {
            Some(e) => e,
            None => break,
        };
        let attr: String = chars[start..=attr_end].iter().collect();
        let is_test = contains_token(&attr, "test") && !attr.contains("not(test");
        i = attr_end + 1;
        if !is_test {
            continue;
        }
        // Skip to the end of the following item: first `{` (brace-match)
        // or `;` at attribute depth.
        let mut j = attr_end + 1;
        let mut end = None;
        while j < chars.len() {
            match chars[j] {
                '{' => {
                    end = find_matching(&chars, j, '{', '}');
                    break;
                }
                ';' => {
                    end = Some(j);
                    break;
                }
                _ => j += 1,
            }
        }
        let end = match end {
            Some(e) => e,
            None => chars.len() - 1,
        };
        for l in line_of[start]..=line_of[end] {
            if l < n_lines {
                skip[l] = true;
            }
        }
        i = end + 1;
    }
    skip
}

/// 0-based line number for each char index.
fn build_line_index(chars: &[char]) -> Vec<usize> {
    let mut out = Vec::with_capacity(chars.len());
    let mut line = 0usize;
    for &c in chars {
        out.push(line);
        if c == '\n' {
            line += 1;
        }
    }
    out
}

/// Index of the delimiter matching `open` at `chars[start]`.
fn find_matching(chars: &[char], start: usize, open: char, close: char) -> Option<usize> {
    let mut depth = 0i64;
    for (off, &c) in chars[start..].iter().enumerate() {
        if c == open {
            depth += 1;
        } else if c == close {
            depth -= 1;
            if depth == 0 {
                return Some(start + off);
            }
        }
    }
    None
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Word-bounded containment: `needle` present and not embedded in a longer
/// identifier (so `Instant` does not match `Instantiate`). `needle` may
/// contain `::` path separators and trailing `!`/`(` punctuation.
fn contains_token(haystack: &str, needle: &str) -> bool {
    let h: Vec<char> = haystack.chars().collect();
    let n: Vec<char> = needle.chars().collect();
    if n.is_empty() || h.len() < n.len() {
        return false;
    }
    'outer: for start in 0..=(h.len() - n.len()) {
        for (k, &nc) in n.iter().enumerate() {
            if h[start + k] != nc {
                continue 'outer;
            }
        }
        let before_ok = start == 0 || !is_ident_char(h[start - 1]) || !is_ident_char(n[0]);
        let last = n[n.len() - 1];
        let after = h.get(start + n.len());
        let after_ok = !is_ident_char(last) || after.map(|&c| !is_ident_char(c)).unwrap_or(true);
        if before_ok && after_ok {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

struct TokenRule {
    id: &'static str,
    /// Directory names (under `src/`) the rule applies to; empty = all.
    dirs: &'static [&'static str],
    /// Files exempt even inside the scope (the facade itself).
    exempt_files: &'static [&'static str],
    tokens: &'static [&'static str],
    message: &'static str,
    fix: &'static str,
}

const TOKEN_RULES: [TokenRule; 5] = [
    TokenRule {
        id: "wall-clock",
        dirs: &["mpisim", "trace", "caliper"],
        exempt_files: &[],
        tokens: &["Instant", "SystemTime", "thread::sleep"],
        message: "wall-clock primitive in a virtual-time module",
        fix: "use util::sync::Deadline for real-time bounds; virtual time comes from the clock model",
    },
    TokenRule {
        id: "hash-iter-artifact",
        dirs: &["caliper", "trace", "thicket", "coordinator", "benchpark", "store", "serve"],
        exempt_files: &[],
        tokens: &["HashMap", "HashSet"],
        message: "hash-ordered container on an artifact-producing path",
        fix: "use BTreeMap/BTreeSet (or sort before emitting); lint:allow with a rationale if lookup-only",
    },
    TokenRule {
        id: "raw-sync",
        dirs: &[],
        exempt_files: &["util/sync.rs"],
        tokens: &["std::sync", "loom::"],
        message: "raw synchronization primitive outside the sync facade",
        fix: "import it from crate::util::sync (the loom-checked facade; Arc is re-exported there)",
    },
    TokenRule {
        id: "park-protocol",
        dirs: &["mpisim"],
        exempt_files: &[],
        tokens: &["thread::sleep", "yield_now", "spin_loop"],
        message: "ad-hoc blocking in the simulator core",
        fix: "block only via Scheduler::park or a facade wait (Notify/OneShot/Monitor)",
    },
    TokenRule {
        id: "unbounded-channel",
        dirs: &[],
        exempt_files: &["util/sync.rs"],
        tokens: &["mpsc::channel"],
        message: "unbounded channel constructor",
        fix: "use util::sync::mpsc::sync_channel(cap) so queues apply backpressure",
    },
];

/// `true` when `path` (normalized, `/`-separated) lies under `dir` —
/// matching a path segment, not a substring.
fn in_dir(path: &str, dir: &str) -> bool {
    path.split('/').any(|seg| seg == dir)
}

fn path_ends_with(path: &str, suffix: &str) -> bool {
    path == suffix || path.ends_with(&format!("/{suffix}"))
}

/// Lint one file's source text under a virtual path (real linting goes
/// through [`lint_tree`]; this entry point is what the fixture tests use).
pub fn lint_source(virtual_path: &str, text: &str) -> Vec<Finding> {
    let norm = virtual_path.replace('\\', "/");
    let masked = mask(text);
    let skip = test_skip_lines(&masked.code);
    let allowed = allowed_lines(&masked);
    let is_allowed =
        |line0: usize, rule: &str| allowed.iter().any(|a| a.target == line0 && a.rule == rule);

    let mut findings = Vec::new();
    for a in &allowed {
        if a.rationale
            || skip.get(a.directive_line).copied().unwrap_or(false)
            || is_allowed(a.directive_line, "bare-allow")
        {
            continue;
        }
        findings.push(Finding {
            file: norm.clone(),
            line: a.directive_line + 1,
            rule: "bare-allow",
            message: format!("suppression `lint:allow({})` carries no rationale", a.rule),
            fix: "append ` -- <why this violation is intended>` to the directive",
        });
    }
    for rule in &TOKEN_RULES {
        if !rule.dirs.is_empty() && !rule.dirs.iter().any(|d| in_dir(&norm, d)) {
            continue;
        }
        if rule.exempt_files.iter().any(|f| path_ends_with(&norm, f)) {
            continue;
        }
        for (line0, line) in masked.code.lines().enumerate() {
            if skip.get(line0).copied().unwrap_or(false) || is_allowed(line0, rule.id) {
                continue;
            }
            for tok in rule.tokens {
                if contains_token(line, tok) {
                    findings.push(Finding {
                        file: norm.clone(),
                        line: line0 + 1,
                        rule: rule.id,
                        message: format!("{} (`{}`)", rule.message, tok),
                        fix: rule.fix,
                    });
                    break;
                }
            }
        }
    }
    findings.extend(panic_in_drop(&norm, &masked, &skip, &is_allowed));
    findings.extend(comm_contract(&norm, &masked, &skip, &is_allowed));
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// The `comm-region` / `halo-order` rules: the comm-region contract over
/// `src/apps`. Every simulated-MPI call site must sit lexically inside a
/// scope that opened a Caliper guard (`.region(` / `.comm_region(`), so
/// the paper's per-region communication attribution (Table I) can never
/// silently lose traffic to an unannotated call. Within one guard scope,
/// receives must be posted before sends (`.irecv(` before `.isend(`) —
/// the rendezvous-safe halo idiom; a wait-family call retires the posted
/// sends and re-arms the check.
///
/// Tracking is lexical: a brace stack where each scope inherits
/// `guarded` / `seen_isend` from its parent, and a closing brace merges
/// `seen_isend` back up (a helper block cannot hide an unretired send).
/// Helper functions whose *callers* hold the guard suppress with
/// `lint:allow(comm-region) -- callers hold the region guard`.
fn comm_contract(
    norm: &str,
    masked: &Masked,
    skip: &[bool],
    is_allowed: &dyn Fn(usize, &str) -> bool,
) -> Vec<Finding> {
    if !in_dir(norm, "apps") {
        return Vec::new();
    }
    // Simulated-MPI call tokens (dotted method calls on a `Rank`).
    const MPI_TOKENS: [&str; 17] = [
        ".isend(",
        ".irecv(",
        ".send(",
        ".recv(",
        ".waitall(",
        ".waitall_recv(",
        ".wait_recv(",
        ".wait_send(",
        ".waitany(",
        ".barrier(",
        ".bcast(",
        ".allreduce_f64(",
        ".allreduce_u64(",
        ".reduce_f64(",
        ".allgatherv(",
        ".alltoallv(",
        ".comm_split(",
    ];
    const GUARD_TOKENS: [&str; 2] = [".comm_region(", ".region("];
    const WAIT_TOKENS: [&str; 5] = [
        ".waitall(",
        ".waitall_recv(",
        ".wait_send(",
        ".wait_recv(",
        ".waitany(",
    ];

    #[derive(Clone, Copy)]
    struct Scope {
        guarded: bool,
        seen_isend: bool,
    }
    #[derive(Clone, Copy, PartialEq)]
    enum Ev {
        Open,
        Close,
        Guard,
        Mpi(usize), // index into MPI_TOKENS
    }

    let mut stack = vec![Scope {
        guarded: false,
        seen_isend: false,
    }];
    let mut findings: Vec<Finding> = Vec::new();
    let mut last_unguarded_line = usize::MAX;
    let mut last_order_line = usize::MAX;

    for (line0, line) in masked.code.lines().enumerate() {
        // Gather this line's events in column order. Braces and tokens
        // never overlap, and the MPI/guard token sets are prefix-free, so
        // plain substring positions are unambiguous.
        let mut evs: Vec<(usize, Ev)> = Vec::new();
        for (col, c) in line.char_indices() {
            match c {
                '{' => evs.push((col, Ev::Open)),
                '}' => evs.push((col, Ev::Close)),
                _ => {}
            }
        }
        for g in GUARD_TOKENS {
            for (col, _) in line.match_indices(g) {
                evs.push((col, Ev::Guard));
            }
        }
        for (ti, t) in MPI_TOKENS.iter().enumerate() {
            for (col, _) in line.match_indices(t) {
                evs.push((col, Ev::Mpi(ti)));
            }
        }
        evs.sort_by_key(|&(col, _)| col);

        let suppressed = skip.get(line0).copied().unwrap_or(false);
        for (_, ev) in evs {
            match ev {
                Ev::Open => {
                    let top = *stack.last().expect("root scope");
                    stack.push(top);
                }
                Ev::Close => {
                    if stack.len() > 1 {
                        let s = stack.pop().expect("non-root scope");
                        // An unretired isend escapes into the parent.
                        stack.last_mut().expect("root scope").seen_isend |= s.seen_isend;
                    }
                }
                Ev::Guard => {
                    let top = stack.last_mut().expect("root scope");
                    top.guarded = true;
                    top.seen_isend = false;
                }
                Ev::Mpi(ti) => {
                    let tok = MPI_TOKENS[ti];
                    let guarded = stack.last().expect("root scope").guarded;
                    if !guarded
                        && !suppressed
                        && !is_allowed(line0, "comm-region")
                        && last_unguarded_line != line0
                    {
                        last_unguarded_line = line0;
                        findings.push(Finding {
                            file: norm.to_string(),
                            line: line0 + 1,
                            rule: "comm-region",
                            message: format!(
                                "MPI call (`{}`) outside a region/comm_region guard scope",
                                tok
                            ),
                            fix: "open `let _g = cali.comm_region(\"…\");` in this scope, or \
                                  lint:allow(comm-region) -- callers hold the region guard",
                        });
                    }
                    if WAIT_TOKENS.contains(&tok) {
                        stack.last_mut().expect("root scope").seen_isend = false;
                    } else if tok == ".isend(" {
                        stack.last_mut().expect("root scope").seen_isend = true;
                    } else if tok == ".irecv(" {
                        let top = stack.last().expect("root scope");
                        if top.seen_isend
                            && !suppressed
                            && !is_allowed(line0, "halo-order")
                            && last_order_line != line0
                        {
                            last_order_line = line0;
                            findings.push(Finding {
                                file: norm.to_string(),
                                line: line0 + 1,
                                rule: "halo-order",
                                message: "receive posted after an unretired isend in the same \
                                          scope"
                                    .to_string(),
                                fix: "post all irecvs before the isends (rendezvous-safe halo \
                                      idiom), or retire the sends with a wait first",
                            });
                        }
                    }
                }
            }
        }
    }
    findings
}

/// The `panic-in-drop` rule: a `Drop` impl that panics aborts the process
/// during unwinding — in the simulator that turns a clean per-rank error
/// into a hang of every other rank. Scan `fn drop` bodies inside
/// `impl … Drop` blocks for panic-capable tokens.
fn panic_in_drop(
    norm: &str,
    masked: &Masked,
    skip: &[bool],
    is_allowed: &dyn Fn(usize, &str) -> bool,
) -> Vec<Finding> {
    const PANICKY: [&str; 6] = [
        "panic!",
        "unwrap(",
        "expect(",
        "assert!",
        "assert_eq!",
        "assert_ne!",
    ];
    let chars: Vec<char> = masked.code.chars().collect();
    let line_of = build_line_index(&chars);
    let mut findings = Vec::new();

    let mut i = 0usize;
    while let Some(pos) = masked.code[i..].find("impl") {
        let start = i + pos;
        i = start + 4;
        // Word boundary + `Drop` appearing in the impl header.
        if (start > 0 && is_ident_char(chars[start - 1]))
            || chars.get(start + 4).map(|&c| is_ident_char(c)).unwrap_or(true)
        {
            continue;
        }
        let brace = match masked.code[start..].find('{') {
            Some(b) => start + b,
            None => continue,
        };
        let header: String = chars[start..brace].iter().collect();
        if !contains_token(&header, "Drop") {
            continue;
        }
        let end = match find_matching(&chars, brace, '{', '}') {
            Some(e) => e,
            None => continue,
        };
        // Locate `fn drop` bodies inside the impl block.
        let body: String = chars[brace..=end].iter().collect();
        let mut j = 0usize;
        while let Some(fp) = body[j..].find("fn drop") {
            let fstart = brace + j + fp;
            j += fp + 7;
            if chars.get(fstart + 7).map(|&c| is_ident_char(c)).unwrap_or(true) {
                continue;
            }
            let fbrace = match masked.code[fstart..].find('{') {
                Some(b) => fstart + b,
                None => continue,
            };
            let fend = match find_matching(&chars, fbrace, '{', '}') {
                Some(e) => e,
                None => continue,
            };
            for l in line_of[fbrace]..=line_of[fend] {
                if skip.get(l).copied().unwrap_or(false) || is_allowed(l, "panic-in-drop") {
                    continue;
                }
                let line = masked.code.lines().nth(l).unwrap_or("");
                for tok in PANICKY {
                    if contains_token(line, tok) {
                        findings.push(Finding {
                            file: norm.to_string(),
                            line: l + 1,
                            rule: "panic-in-drop",
                            message: format!(
                                "possible panic in Drop (`{}`) would abort mid-unwind",
                                tok
                            ),
                            fix: "degrade gracefully (let _ = …, if let) — Drop must never panic",
                        });
                        break;
                    }
                }
            }
        }
        i = end + 1;
    }
    findings
}

/// Lint every `.rs` file under `root` (deterministic order), returning all
/// findings. Paths in findings are relative to `root`'s parent so they
/// read like repo paths.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for f in files {
        let text = std::fs::read_to_string(&f)?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(&f)
            .to_string_lossy()
            .replace('\\', "/");
        // Re-anchor under `src/` so dir scoping sees the module path.
        findings.extend(lint_source(&format!("src/{rel}"), &text));
    }
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_matching_is_word_bounded() {
        assert!(contains_token("let t = Instant::now();", "Instant"));
        assert!(!contains_token("/// Instantiate the pipeline", "Instant"));
        assert!(!contains_token("let reinstant = 3;", "Instant"));
        assert!(contains_token("use std::sync::{Arc, Mutex};", "std::sync"));
        assert!(contains_token("x.unwrap()", "unwrap("));
    }

    #[test]
    fn strings_and_comments_are_masked() {
        let src = "fn f() { let s = \"Instant\"; } // Instant\n/* SystemTime */\n";
        let m = mask(src);
        assert!(!m.code.contains("Instant"));
        assert!(!m.code.contains("SystemTime"));
        assert_eq!(m.code.lines().count(), src.lines().count());
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "use x;\n#[cfg(test)]\nmod tests {\n    use std::time::Instant;\n}\n";
        let f = lint_source("src/mpisim/x.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn allow_directive_covers_next_code_line_only() {
        let src = "// lint:allow(hash-iter-artifact) -- lookup-only\n// intern table.\nuse std::collections::HashMap;\ntype T = HashMap<u32, u32>;\n";
        let f = lint_source("src/trace/x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 4);
        assert_eq!(f[0].rule, "hash-iter-artifact");
    }

    #[test]
    fn bare_allow_suppresses_but_is_reported() {
        // Old colon-form rationale no longer counts as a rationale.
        let src = "// lint:allow(hash-iter-artifact): legacy rationale\nuse std::collections::HashMap;\n";
        let f = lint_source("src/trace/x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "bare-allow");
        assert_eq!(f[0].line, 1);
        // The underlying finding stays suppressed — a bare allow is one
        // finding, not two.
        assert!(f.iter().all(|x| x.rule != "hash-iter-artifact"), "{f:?}");
    }

    #[test]
    fn escaped_newline_in_string_keeps_line_numbers() {
        // A `\`-continued string spans two physical lines; the finding
        // after it must land on its true line.
        let src = "fn f() -> &'static str {\n    \"one \\\n     two\"\n}\nuse std::time::Instant;\n";
        let f = lint_source("src/mpisim/x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "wall-clock");
        assert_eq!(f[0].line, 5, "{f:?}");
    }

    #[test]
    fn comm_region_requires_guard_in_apps_only() {
        let src = "fn halo(rank: &Rank) {\n    rank.barrier();\n}\n";
        let f = lint_source("src/apps/toy/driver.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "comm-region");
        assert_eq!(f[0].line, 2);
        // The same source outside apps/ is not the lint's business.
        assert!(lint_source("src/mpisim/x.rs", src).is_empty());
    }

    #[test]
    fn guard_scope_covers_nested_blocks_and_resets_on_close() {
        let src = "fn step(rank: &Rank, cali: &C) {\n    {\n        let _g = cali.comm_region(\"halo\");\n        for p in peers {\n            rank.irecv(p, 0);\n        }\n    }\n    rank.barrier();\n}\n";
        let f = lint_source("src/apps/toy/driver.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "comm-region");
        assert_eq!(f[0].line, 8, "guard must not leak out of its scope: {f:?}");
    }

    #[test]
    fn halo_order_flags_irecv_after_isend_until_wait_retires() {
        let src = "fn bad(rank: &Rank, cali: &C) {\n    let _g = cali.comm_region(\"halo\");\n    rank.isend(1, 0, 8);\n    rank.irecv(1, 0);\n    rank.waitall(&mut reqs);\n    rank.irecv(1, 0);\n}\n";
        let f = lint_source("src/apps/toy/driver.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "halo-order");
        assert_eq!(f[0].line, 4, "the post-wait irecv is re-armed: {f:?}");
    }

    #[test]
    fn raw_string_with_hashes_is_masked() {
        let src = "let s = r#\"std::sync::Mutex \"inner\" HashMap\"#;\n";
        let f = lint_source("src/caliper/x.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn lifetimes_do_not_open_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\nuse std::sync::Mutex;\n";
        let f = lint_source("src/util/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "raw-sync");
        assert_eq!(f[0].line, 2);
    }
}
