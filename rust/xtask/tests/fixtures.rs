//! Every lint rule is demonstrated by a fixture that trips it and guarded
//! by a clean fixture that must stay silent. The fixtures live under
//! `fixtures/` and are linted under *virtual* paths so the directory
//! scoping is exercised without polluting `rust/src`.

use xtask::{lint_source, Finding};

fn rules_hit(findings: &[Finding]) -> Vec<&'static str> {
    let mut rules: Vec<&'static str> = findings.iter().map(|f| f.rule).collect();
    rules.dedup();
    rules
}

fn count(findings: &[Finding], rule: &str) -> usize {
    findings.iter().filter(|f| f.rule == rule).count()
}

#[test]
fn wall_clock_fixture_trips() {
    let f = lint_source(
        "src/mpisim/clock.rs",
        include_str!("../fixtures/wall_clock.rs"),
    );
    assert_eq!(count(&f, "wall-clock"), 3, "{f:#?}");
    assert_eq!(rules_hit(&f), ["wall-clock"]);
    // The test module's Instant must NOT be flagged.
    assert!(f.iter().all(|x| x.line < 15), "{f:#?}");
}

#[test]
fn wall_clock_fixture_is_scope_gated() {
    // The same source under a non-virtual-time path is clean.
    let f = lint_source(
        "src/benchutil/clock.rs",
        include_str!("../fixtures/wall_clock.rs"),
    );
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn hash_iter_fixture_trips_and_allow_suppresses() {
    let f = lint_source(
        "src/caliper/report.rs",
        include_str!("../fixtures/hash_iter.rs"),
    );
    assert_eq!(count(&f, "hash-iter-artifact"), 2, "{f:#?}");
    // The lint:allow'd intern-table line (9) is not among the findings.
    assert!(f.iter().all(|x| x.line != 9), "{f:#?}");
}

#[test]
fn raw_sync_fixture_trips() {
    let f = lint_source(
        "src/runtime/gate.rs",
        include_str!("../fixtures/raw_sync.rs"),
    );
    assert_eq!(count(&f, "raw-sync"), 3, "{f:#?}");
    assert_eq!(rules_hit(&f), ["raw-sync"]);
}

#[test]
fn raw_sync_facade_file_is_exempt() {
    let f = lint_source(
        "src/util/sync.rs",
        include_str!("../fixtures/raw_sync.rs"),
    );
    assert!(f.is_empty(), "the facade itself may name std::sync: {f:#?}");
}

#[test]
fn park_protocol_fixture_trips() {
    let f = lint_source(
        "src/mpisim/poll.rs",
        include_str!("../fixtures/park_protocol.rs"),
    );
    assert_eq!(count(&f, "park-protocol"), 3, "{f:#?}");
    // thread::sleep double-reports as wall-clock in mpisim — intended.
    assert_eq!(count(&f, "wall-clock"), 1, "{f:#?}");
}

#[test]
fn unbounded_channel_fixture_trips() {
    let f = lint_source(
        "src/coordinator/queue.rs",
        include_str!("../fixtures/unbounded_channel.rs"),
    );
    assert_eq!(count(&f, "unbounded-channel"), 1, "{f:#?}");
    assert_eq!(rules_hit(&f), ["unbounded-channel"]);
}

#[test]
fn panic_in_drop_fixture_trips() {
    let f = lint_source(
        "src/util/guard.rs",
        include_str!("../fixtures/panic_in_drop.rs"),
    );
    assert_eq!(count(&f, "panic-in-drop"), 1, "{f:#?}");
    assert_eq!(rules_hit(&f), ["panic-in-drop"]);
    // Quiet's graceful drop and the non-drop unwraps stay silent.
    assert_eq!(f[0].line, 10, "{f:#?}");
}

#[test]
fn bare_allow_fixture_trips_without_unsuppressing() {
    let f = lint_source("src/trace/bare.rs", include_str!("../fixtures/bare_allow.rs"));
    assert_eq!(count(&f, "bare-allow"), 1, "{f:#?}");
    assert_eq!(f[0].line, 4, "{f:#?}");
    // Both HashMaps stay suppressed — a bare allow is one finding (the
    // missing rationale), never two.
    assert_eq!(rules_hit(&f), ["bare-allow"], "{f:#?}");
}

#[test]
fn comm_region_fixture_trips_on_the_unguarded_call_only() {
    let f = lint_source(
        "src/apps/fixture/driver.rs",
        include_str!("../fixtures/comm_region.rs"),
    );
    assert_eq!(count(&f, "comm-region"), 1, "{f:#?}");
    assert_eq!(rules_hit(&f), ["comm-region"], "{f:#?}");
    // Line 9: the call after the guard's scope closed. The guarded call
    // (7) and the allow'd helper (14) stay silent.
    assert_eq!(f[0].line, 9, "{f:#?}");
}

#[test]
fn comm_region_fixture_is_scope_gated_to_apps() {
    let f = lint_source(
        "src/benchutil/driver.rs",
        include_str!("../fixtures/comm_region.rs"),
    );
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn halo_order_fixture_trips_after_scope_escape_until_wait_retires() {
    let f = lint_source(
        "src/apps/fixture/halo.rs",
        include_str!("../fixtures/halo_order.rs"),
    );
    assert_eq!(count(&f, "halo-order"), 1, "{f:#?}");
    assert_eq!(rules_hit(&f), ["halo-order"], "{f:#?}");
    // Line 12: the isend escaped its loop scope; the post-waitall irecv
    // (14) is re-armed and clean.
    assert_eq!(f[0].line, 12, "{f:#?}");
}

#[test]
fn store_serve_fixture_trips_the_new_coverage() {
    // The service PR extended hash-iter-artifact to `store/` and `serve/`;
    // the tree-wide sync rules must keep holding there too.
    for path in ["src/store/index.rs", "src/serve/conn.rs"] {
        let f = lint_source(path, include_str!("../fixtures/store_serve.rs"));
        assert_eq!(count(&f, "hash-iter-artifact"), 2, "{path}: {f:#?}");
        assert_eq!(count(&f, "raw-sync"), 1, "{path}: {f:#?}");
        assert_eq!(count(&f, "unbounded-channel"), 1, "{path}: {f:#?}");
    }
    // Outside store/serve the artifact-order scope does not apply, but
    // raw-sync and unbounded-channel are tree-wide.
    let f = lint_source(
        "src/runtime/queue.rs",
        include_str!("../fixtures/store_serve.rs"),
    );
    assert_eq!(count(&f, "hash-iter-artifact"), 0, "{f:#?}");
    assert_eq!(count(&f, "raw-sync"), 1, "{f:#?}");
    assert_eq!(count(&f, "unbounded-channel"), 1, "{f:#?}");
}

#[test]
fn masking_fixture_reports_one_finding_on_its_true_line() {
    // Raw strings (hashed + multi-line), a `\`-continued string, and
    // cfg(all/any(test)) items must all stay silent — and must not shift
    // the line number of the one real finding below them.
    let f = lint_source(
        "src/mpisim/masked.rs",
        include_str!("../fixtures/masking.rs"),
    );
    assert_eq!(count(&f, "wall-clock"), 1, "{f:#?}");
    assert_eq!(rules_hit(&f), ["wall-clock"], "{f:#?}");
    assert_eq!(f[0].line, 18, "{f:#?}");
}

#[test]
fn clean_fixture_is_clean_under_strictest_scope() {
    let f = lint_source("src/caliper/clean.rs", include_str!("../fixtures/clean.rs"));
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn every_rule_has_a_tripping_fixture() {
    // The acceptance bar: every active rule is demonstrated by a fixture
    // that fails it.
    let all = [
        lint_source(
            "src/mpisim/clock.rs",
            include_str!("../fixtures/wall_clock.rs"),
        ),
        lint_source(
            "src/caliper/report.rs",
            include_str!("../fixtures/hash_iter.rs"),
        ),
        lint_source(
            "src/runtime/gate.rs",
            include_str!("../fixtures/raw_sync.rs"),
        ),
        lint_source(
            "src/mpisim/poll.rs",
            include_str!("../fixtures/park_protocol.rs"),
        ),
        lint_source(
            "src/coordinator/queue.rs",
            include_str!("../fixtures/unbounded_channel.rs"),
        ),
        lint_source(
            "src/util/guard.rs",
            include_str!("../fixtures/panic_in_drop.rs"),
        ),
        lint_source("src/trace/bare.rs", include_str!("../fixtures/bare_allow.rs")),
        lint_source(
            "src/apps/fixture/driver.rs",
            include_str!("../fixtures/comm_region.rs"),
        ),
        lint_source(
            "src/apps/fixture/halo.rs",
            include_str!("../fixtures/halo_order.rs"),
        ),
        lint_source(
            "src/store/index.rs",
            include_str!("../fixtures/store_serve.rs"),
        ),
    ];
    for rule in xtask::RULES {
        assert!(
            all.iter().any(|f| f.iter().any(|x| x.rule == rule)),
            "rule {rule} has no tripping fixture"
        );
    }
    for f in all.iter().flatten() {
        // Reporting contract: file:line, rule id, and a fix hint.
        let s = f.to_string();
        assert!(s.contains(&format!(":{}:", f.line)), "{s}");
        assert!(s.contains(&format!("[{}]", f.rule)), "{s}");
        assert!(s.contains("fix:"), "{s}");
    }
}
