//! The real tree must satisfy the determinism contract: linting
//! `rust/src` produces zero findings. This makes `cargo test` fail the
//! moment a raw primitive, wall clock, hash-ordered artifact, or
//! unbounded queue sneaks back in — the same gate CI runs as
//! `cargo xtask lint`.

use std::path::PathBuf;

#[test]
fn determinism_lint_is_clean_on_the_tree() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../src");
    let findings = xtask::lint_tree(&root).expect("rust/src is readable");
    for f in &findings {
        eprintln!("{f}");
    }
    assert!(
        findings.is_empty(),
        "determinism lint found {} violation(s) in rust/src — see stderr",
        findings.len()
    );
}
