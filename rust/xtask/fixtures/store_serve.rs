//! Fixture: artifact-determinism coverage for the store/serve subsystem.
//! Under `src/store/` or `src/serve/` the hash-ordered containers below
//! trip `hash-iter-artifact`; the raw channel line trips `raw-sync` and
//! `unbounded-channel` everywhere.

use std::collections::HashMap;

pub struct Index {
    entries: HashMap<String, u64>,
}

pub fn queue() {
    let (tx, rx) = std::sync::mpsc::channel::<u64>();
    let _ = (tx, rx);
}
