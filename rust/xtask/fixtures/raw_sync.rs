// Fixture: trips `raw-sync` (any src/ path outside util/sync.rs).
// Not compiled — exercised by tests/fixtures.rs only.
use std::sync::{Condvar, Mutex};

pub struct Gate {
    flag: Mutex<bool>,
    cv: Condvar,
}

pub fn atomics() -> u64 {
    // finding: atomics must come through the facade too
    let c = std::sync::atomic::AtomicU64::new(0);
    c.load(std::sync::atomic::Ordering::Relaxed)
}

// The string/comment forms must NOT trip the lint:
pub const DOC: &str = "std::sync::Mutex is banned outside the facade";
// std::sync::Mutex (comment mention)
