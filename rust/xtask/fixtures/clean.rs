// Fixture: a file the lint must pass untouched, exercising every masking
// and scoping path at once. Not compiled — exercised by tests/fixtures.rs.
use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::sync::{Arc, Deadline, Mutex, Notify};

pub struct Clean<'a> {
    name: &'a str,
    regions: BTreeMap<String, f64>,
    notify: Arc<Notify>,
    guard: Mutex<u64>,
}

impl<'a> Clean<'a> {
    pub fn wait(&self, timeout: Duration) -> bool {
        let deadline = Deadline::after(timeout);
        let snapshot = self.notify.snapshot();
        if deadline.expired() {
            return false;
        }
        self.notify.wait_changed(snapshot, &deadline)
    }

    pub fn doc(&self) -> String {
        // Instantiate (word-boundary check: must not match `Instant`).
        let raw = r#"Instant SystemTime HashMap "std::sync::Mutex""#;
        let plain = "thread::sleep inside a string is fine";
        let ch = 'x';
        let _ = *self.guard.lock().unwrap();
        format!("{} {raw} {plain} {ch} {:?}", self.name, self.regions.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn timed_in_tests_is_fine() {
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(1));
        assert!(t0.elapsed() >= Duration::from_millis(1));
    }
}
