// Fixture: masking + skipping edge cases — hashed and multi-line raw
// strings, a `\`-continued plain string (regression: the masker once
// swallowed the escaped newline and shifted every later finding up a
// line), and `#[cfg(all(test, …))]` / `#[cfg(any(test, …))]` items.
// Exactly one line below may be reported, on its true line number.

pub const DOC: &str = r#"Instant SystemTime "quoted" std::sync::Mutex"#;

pub const MULTI: &str = r##"
thread::sleep HashMap
"##;

pub const CONT: &str = "a continued \
    string literal";

pub fn real() -> u64 {
    // the one true finding, on its true line
    std::time::Instant::now().elapsed().as_nanos() as u64
}

#[cfg(all(test, not(loom)))]
mod tests {
    use std::time::Instant;
}

#[cfg(any(test, loom))]
mod loom_tests {
    use std::time::SystemTime;
}
