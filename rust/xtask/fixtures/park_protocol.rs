// Fixture: trips `park-protocol` (linted under a virtual mpisim/ path).
// Not compiled — exercised by tests/fixtures.rs only.
use std::time::Duration;

pub fn spin_wait(ready: &dyn Fn() -> bool) {
    while !ready() {
        std::thread::sleep(Duration::from_micros(50)); // finding
    }
}

pub fn busy_wait(ready: &dyn Fn() -> bool) {
    while !ready() {
        std::thread::yield_now(); // finding
        std::hint::spin_loop(); // finding
    }
}
