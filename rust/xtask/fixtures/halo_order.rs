// Fixture: halo ordering — receives post before sends; a wait-family
// call retires the posted sends and re-arms the check.

pub fn exchange(rank: &mut Rank, cali: &Caliper) {
    let _g = cali.comm_region("halo");
    for p in peers() {
        rank.irecv(p, 0); // clean: receives first
    }
    for p in peers() {
        rank.isend(p, 0);
    }
    rank.irecv(0, 1); // finding: the unretired isend escaped the loop scope
    rank.waitall(reqs);
    rank.irecv(0, 2); // clean: the wait retired the sends
}
