// Fixture: the comm-region contract — every simulated-MPI call in apps/
// must sit lexically inside a `region`/`comm_region` guard scope.

pub fn step(rank: &mut Rank, cali: &Caliper) {
    {
        let _g = cali.comm_region("halo");
        rank.barrier(); // guarded: clean
    }
    rank.barrier(); // finding: the guard died with its scope
}

pub fn helper(rank: &mut Rank) {
    // lint:allow(comm-region) -- callers hold the region guard.
    rank.barrier();
}
