// Fixture: trips `wall-clock` (linted under a virtual mpisim/ path).
// Not compiled — exercised by tests/fixtures.rs only.
use std::time::Instant;

pub fn now_seconds() -> f64 {
    let t0 = Instant::now(); // finding: wall clock in virtual-time code
    t0.elapsed().as_secs_f64()
}

pub fn stamp() -> u64 {
    let t = std::time::SystemTime::now(); // finding
    t.elapsed().map(|d| d.as_secs()).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    // Exempt: tests may measure real time.
    use std::time::Instant;

    #[test]
    fn timed() {
        let _ = Instant::now();
    }
}
