// Fixture: trips `hash-iter-artifact` (linted under a virtual caliper/
// path). Not compiled — exercised by tests/fixtures.rs only.
use std::collections::HashMap;

pub struct Report {
    // finding: hash order would reach the artifact through `emit`
    regions: HashMap<String, f64>,
    // lint:allow(hash-iter-artifact) -- lookup-only index, never iterated.
    index: std::collections::HashMap<String, u32>,
}

impl Report {
    pub fn emit(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.regions {
            out.push_str(&format!("{k}={v}\n"));
        }
        let _ = self.index.len();
        out
    }
}
