// Fixture: trips `unbounded-channel` (any src/ path outside util/sync.rs).
// Not compiled — exercised by tests/fixtures.rs only.
use crate::util::sync::mpsc;

pub fn queue() {
    let (tx, rx) = mpsc::channel::<u64>(); // finding: unbounded
    tx.send(1).unwrap();
    let _ = rx.recv();
}

pub fn bounded_is_fine() {
    let (tx, rx) = mpsc::sync_channel::<u64>(8);
    tx.send(1).unwrap();
    let _ = rx.recv();
}
