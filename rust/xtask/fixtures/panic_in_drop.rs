// Fixture: trips `panic-in-drop` (any src/ path).
// Not compiled — exercised by tests/fixtures.rs only.
pub struct Guard {
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for Guard {
    fn drop(&mut self) {
        // finding: a panic here aborts the process mid-unwind
        self.handle.take().unwrap().join().expect("worker died");
    }
}

pub struct Quiet {
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for Quiet {
    fn drop(&mut self) {
        // Clean: degrades gracefully, no panic path.
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Guard {
    pub fn finish(mut self) {
        // Outside `fn drop`: unwrap is allowed here.
        self.handle.take().unwrap().join().unwrap();
    }
}
