// Fixture: a `lint:allow` without a `-- rationale` still suppresses the
// underlying finding but is itself reported as `bare-allow`.

// lint:allow(hash-iter-artifact)
pub type Bare = std::collections::HashMap<u32, u32>;

// lint:allow(hash-iter-artifact) -- lookup-only; the sanctioned form.
pub type Annotated = std::collections::HashMap<u32, u32>;
