# Make `compile.*` importable when pytest is invoked from the repo root
# (`pytest python/tests/`) as well as from python/ itself.
import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

# The kernel tests exercise JAX/Pallas against pure-Python references and
# property-test with hypothesis. When those extras are not installed (CI
# images without the accelerator stack), skip collection gracefully rather
# than erroring at import time.
_required = ("jax", "numpy", "hypothesis")
_missing = [m for m in _required if importlib.util.find_spec(m) is None]
if _missing:
    collect_ignore_glob = ["tests/*"]
    print(
        "conftest: skipping python/tests — missing optional deps: "
        + ", ".join(_missing),
        file=sys.stderr,
    )
