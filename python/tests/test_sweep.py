"""L1 correctness: Pallas sweep plane kernel vs oracle, plus transport
properties of the L2 local sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, sweep

jax.config.update("jax_platform_name", "cpu")


def rand_plane(seed, ny, nz, g, d):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    mk = lambda k: jax.random.uniform(k, (ny, nz, g, d), jnp.float32, 0.0, 2.0)
    sig = jax.random.uniform(ks[3], (ny, nz), jnp.float32, 0.1, 5.0)
    return mk(ks[0]), mk(ks[1]), mk(ks[2]), sig


class TestSweepPlane:
    def test_matches_ref_canonical(self):
        px, py, pz, sig = rand_plane(0, 8, 8, 8, 8)
        got = sweep.sweep_plane(px, py, pz, sig, q=1.0)
        want = ref.sweep_plane_ref(px, py, pz, sig, 1.0, 1.0, 1.0, 1.0)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-6)

    def test_anisotropic_cells(self):
        px, py, pz, sig = rand_plane(1, 4, 6, 2, 3)
        got = sweep.sweep_plane(px, py, pz, sig, q=0.5, dx=0.5, dy=2.0, dz=1.5)
        want = ref.sweep_plane_ref(px, py, pz, sig, 0.5, 0.5, 2.0, 1.5)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-6)

    def test_equilibrium_flux(self):
        # If psi_in = q / sigt on all faces, psi = psi_in (DD fixed point)
        # and outgoing equals incoming.
        ny = nz = g = d = 4
        sig = jnp.full((ny, nz), 2.0, jnp.float32)
        q = 3.0
        eq = jnp.full((ny, nz, g, d), q / 2.0, jnp.float32)
        ox, oy, oz, phi = sweep.sweep_plane(eq, eq, eq, sig, q=q)
        np.testing.assert_allclose(ox, eq, rtol=1e-6)
        np.testing.assert_allclose(phi, q / 2.0, rtol=1e-6)

    def test_absorption_attenuates(self):
        # With zero source and huge sigma_t, outgoing flux magnitude drops.
        ny = nz = g = d = 4
        inc = jnp.ones((ny, nz, g, d), jnp.float32)
        sig = jnp.full((ny, nz), 1e3, jnp.float32)
        ox, _, _, phi = sweep.sweep_plane(inc, inc, inc, sig, q=0.0)
        assert float(jnp.max(jnp.abs(ox))) < 1.0
        assert float(jnp.max(phi)) < 0.1


@settings(max_examples=8, deadline=None)
@given(
    ny=st.integers(1, 5),
    nz=st.integers(1, 5),
    g=st.integers(1, 4),
    d=st.integers(1, 4),
    q=st.floats(0.0, 3.0),
    seed=st.integers(0, 2**16),
)
def test_sweep_plane_hypothesis(ny, nz, g, d, q, seed):
    px, py, pz, sig = rand_plane(seed, ny, nz, g, d)
    got = sweep.sweep_plane(px, py, pz, sig, q=q)
    want = ref.sweep_plane_ref(px, py, pz, sig, q, 1.0, 1.0, 1.0)
    for gg, w in zip(got, want):
        np.testing.assert_allclose(gg, w, rtol=1e-5, atol=1e-5)


class TestLocalSweep:
    def test_shapes(self):
        nx = ny = nz = 4
        g = d = 2
        bc = jnp.ones((ny, nz, g, d), jnp.float32)
        sig = jnp.full((nx, ny, nz), 1.0, jnp.float32)
        ox, oy, oz, phi = model.kripke_sweep_local(bc, bc, bc, sig)
        assert ox.shape == (ny, nz, g, d)
        assert phi.shape == (nx, ny, nz, g)

    def test_scan_equals_manual_loop(self):
        nx, ny, nz, g, d = 3, 4, 4, 2, 2
        ks = jax.random.split(jax.random.PRNGKey(5), 4)
        bcx = jax.random.uniform(ks[0], (ny, nz, g, d), jnp.float32)
        bcy = jax.random.uniform(ks[1], (ny, nz, g, d), jnp.float32)
        bcz = jax.random.uniform(ks[2], (ny, nz, g, d), jnp.float32)
        sig = jax.random.uniform(ks[3], (nx, ny, nz), jnp.float32, 0.5, 2.0)
        ox, oy, oz, phi = model.kripke_sweep_local(bcx, bcy, bcz, sig)
        px, py, pz = bcx, bcy, bcz
        for i in range(nx):
            px, py, pz, phi_i = ref.sweep_plane_ref(
                px, py, pz, sig[i], 1.0, 1.0, 1.0, 1.0
            )
            np.testing.assert_allclose(phi[i], phi_i, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ox, px, rtol=1e-5, atol=1e-6)

    def test_flux_decays_through_absorber(self):
        nx = 6
        ny = nz = g = d = 2
        bc = jnp.ones((ny, nz, g, d), jnp.float32)
        sig = jnp.full((nx, ny, nz), 50.0, jnp.float32)
        _, _, _, phi = model.kripke_sweep_local(bc, bc, bc, sig)
        # flux magnitude attenuates strongly through the absorber (diamond
        # difference oscillates in sign at coarse cells, so compare |phi|)
        mags = [float(jnp.mean(jnp.abs(phi[i]))) for i in range(nx)]
        assert mags[-1] < 0.2 * mags[0], mags
