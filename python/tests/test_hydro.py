"""L1 correctness: Pallas corner-force kernel vs einsum oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import hydro, ref

jax.config.update("jax_platform_name", "cpu")


def rand_elems(seed, e, q, n, dim):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    b = jax.random.normal(k1, (e, q, n), jnp.float32)
    s = jax.random.normal(k2, (e, q, dim), jnp.float32)
    return b, s


class TestCornerForces:
    def test_matches_ref_canonical(self):
        b, s = rand_elems(0, 64, 16, 16, 2)
        got = hydro.corner_forces(b, s)
        want = ref.corner_forces_ref(b, s)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_single_block(self):
        b, s = rand_elems(1, 8, 4, 6, 3)
        got = hydro.corner_forces(b, s, block_e=8)
        want = ref.corner_forces_ref(b, s)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_non_divisible_block_falls_back(self):
        b, s = rand_elems(2, 10, 4, 4, 2)
        got = hydro.corner_forces(b, s, block_e=16)
        want = ref.corner_forces_ref(b, s)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_identity_bmat(self):
        # With B = I (Q == N), F = stress.
        e, q = 4, 6
        b = jnp.tile(jnp.eye(q, dtype=jnp.float32)[None], (e, 1, 1))
        s = jax.random.normal(jax.random.PRNGKey(3), (e, q, 2), jnp.float32)
        got = hydro.corner_forces(b, s, block_e=4)
        np.testing.assert_allclose(got, s, rtol=1e-6)

    def test_linearity(self):
        b, s = rand_elems(4, 16, 8, 8, 2)
        f1 = hydro.corner_forces(b, s)
        f2 = hydro.corner_forces(b, 2.0 * s)
        np.testing.assert_allclose(f2, 2.0 * f1, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    e=st.sampled_from([1, 2, 4, 8, 16]),
    q=st.integers(1, 8),
    n=st.integers(1, 8),
    dim=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**16),
)
def test_forces_hypothesis(e, q, n, dim, seed):
    b, s = rand_elems(seed, e, q, n, dim)
    got = hydro.corner_forces(b, s, block_e=max(1, e // 2))
    want = ref.corner_forces_ref(b, s)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_model_laghos_forces_wavespeed():
    b, s = rand_elems(5, 64, 16, 16, 2)
    forces, ws = model.laghos_forces(b, s)
    assert forces.shape == (64, 16, 2)
    np.testing.assert_allclose(ws, ref.max_wavespeed_ref(s), rtol=1e-6)
