"""L1 correctness: Pallas stencil kernels vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, stencil

jax.config.update("jax_platform_name", "cpu")


def rand_problem(key, nx, ny, nz, dtype=jnp.float32):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    u = jax.random.normal(k1, (nx + 2, ny + 2, nz + 2), dtype)
    f = jax.random.normal(k2, (nx, ny, nz), dtype)
    return u, f


class TestJacobiStep:
    def test_matches_ref_canonical(self):
        u, f = rand_problem(0, 16, 16, 16)
        got = stencil.jacobi_step(u, f, omega=0.8, h2=1.0)
        want = ref.jacobi_step_ref(u, f, 0.8, 1.0)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_matches_ref_anisotropic_tile(self):
        u, f = rand_problem(1, 4, 8, 6)
        got = stencil.jacobi_step(u, f, omega=0.6, h2=0.25)
        want = ref.jacobi_step_ref(u, f, 0.6, 0.25)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_omega_zero_is_identity(self):
        u, f = rand_problem(2, 4, 4, 4)
        got = stencil.jacobi_step(u, f, omega=0.0, h2=1.0)
        np.testing.assert_allclose(got, u[1:-1, 1:-1, 1:-1], rtol=1e-6)

    def test_constant_field_is_fixed_point(self):
        # With f = 0, a constant field is a fixed point of the smoother.
        u = jnp.ones((6, 6, 6), jnp.float32)
        f = jnp.zeros((4, 4, 4), jnp.float32)
        got = stencil.jacobi_step(u, f, omega=0.8, h2=1.0)
        np.testing.assert_allclose(got, jnp.ones((4, 4, 4)), rtol=1e-6)

    def test_float64(self):
        u, f = rand_problem(3, 4, 4, 4, jnp.float32)
        u = u.astype(jnp.float64) if jax.config.read("jax_enable_x64") else u
        got = stencil.jacobi_step(u, f)
        want = ref.jacobi_step_ref(u, f, 0.8, 1.0)
        np.testing.assert_allclose(got, want, rtol=1e-6)


class TestResidual:
    def test_matches_ref(self):
        u, f = rand_problem(4, 8, 8, 8)
        got = stencil.residual(u, f, h2=1.0)
        want = ref.residual_ref(u, f, 1.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_residual_for_exact_solution(self):
        # u = 0 with f = 0 → r = 0.
        u = jnp.zeros((6, 6, 6), jnp.float32)
        f = jnp.zeros((4, 4, 4), jnp.float32)
        got = stencil.residual(u, f)
        np.testing.assert_allclose(got, 0.0, atol=1e-7)

    def test_smoothing_reduces_residual(self):
        # One Jacobi sweep on a zero guess must reduce ||r|| for a Poisson
        # problem with zero BCs.
        f = jax.random.normal(jax.random.PRNGKey(7), (8, 8, 8), jnp.float32)
        u = jnp.zeros((10, 10, 10), jnp.float32)
        r0 = float(jnp.linalg.norm(ref.residual_ref(u, f, 1.0)))
        unew = ref.jacobi_step_ref(u, f, 0.8, 1.0)
        u1 = u.at[1:-1, 1:-1, 1:-1].set(unew)
        r1 = float(jnp.linalg.norm(ref.residual_ref(u1, f, 1.0)))
        assert r1 < r0


@settings(max_examples=10, deadline=None)
@given(
    nx=st.integers(2, 6),
    ny=st.integers(2, 6),
    nz=st.integers(2, 6),
    omega=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**16),
)
def test_jacobi_hypothesis_shapes(nx, ny, nz, omega, seed):
    u, f = rand_problem(seed, nx, ny, nz)
    got = stencil.jacobi_step(u, f, omega=omega, h2=1.0)
    want = ref.jacobi_step_ref(u, f, omega, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    nx=st.integers(2, 5),
    h2=st.floats(0.01, 4.0),
    seed=st.integers(0, 2**16),
)
def test_residual_hypothesis(nx, h2, seed):
    u, f = rand_problem(seed, nx, nx, nx)
    got = stencil.residual(u, f, h2=h2)
    want = ref.residual_ref(u, f, h2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_vmem_estimate_is_small():
    # The canonical tile must fit comfortably in a 16 MiB VMEM budget.
    assert stencil.vmem_footprint_bytes(32, 32, 32) < 2 << 20
