"""AOT pipeline: every canonical model lowers to parseable HLO text and the
manifest describes it faithfully."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(str(out))
    return out, manifest


def test_all_models_lowered(artifacts):
    out, manifest = artifacts
    assert set(manifest) == {
        "amg_jacobi",
        "amg_residual",
        "kripke_sweep",
        "laghos_forces",
    }
    for name, entry in manifest.items():
        path = out / entry["file"]
        assert path.exists(), name
        text = path.read_text()
        assert "ENTRY" in text, f"{name} HLO text lacks ENTRY"
        assert "HloModule" in text


def test_manifest_written_and_consistent(artifacts):
    out, manifest = artifacts
    on_disk = json.loads((out / "manifest.json").read_text())
    assert on_disk == manifest


def test_manifest_shapes_match_model(artifacts):
    _, manifest = artifacts
    k = manifest["kripke_sweep"]
    assert k["inputs"][0]["shape"] == [8, 8, 8, 8]
    assert k["inputs"][3]["shape"] == [8, 8, 8]
    assert k["outputs"][3]["shape"] == [8, 8, 8, 8]  # phi (nx, ny, nz, G)
    a = manifest["amg_jacobi"]
    assert a["inputs"][0]["shape"] == [18, 18, 18]
    assert a["outputs"][0]["shape"] == [16, 16, 16]
    l = manifest["laghos_forces"]
    assert l["outputs"][0]["shape"] == [64, 16, 2]
    assert l["outputs"][1]["shape"] == []  # scalar wavespeed


def test_hlo_text_declares_expected_signatures(artifacts):
    """The emitted HLO text must carry the canonical parameter/result shapes
    the Rust loader (runtime::artifact) expects. Full execute-and-compare of
    the text artifacts happens in the Rust integration tests
    (rust/tests/runtime_roundtrip.rs), which load these exact files through
    PJRT — the consumer of record."""
    out, manifest = artifacts
    amg = (out / manifest["amg_jacobi"]["file"]).read_text()
    assert "f32[18,18,18]" in amg
    assert "f32[16,16,16]" in amg
    kripke = (out / manifest["kripke_sweep"]["file"]).read_text()
    assert "f32[8,8,8,8]" in kripke
    laghos = (out / manifest["laghos_forces"]["file"]).read_text()
    assert "f32[64,16,16]" in laghos
    assert "f32[64,16,2]" in laghos
    # return_tuple=True: the entry root must be a tuple
    for name in manifest:
        text = (out / manifest[name]["file"]).read_text()
        assert "ENTRY" in text and "tuple(" in text, name
