"""AOT lowering: JAX (L2, calling L1 Pallas) → HLO text artifacts.

HLO *text* is the interchange format, NOT serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the `xla` crate) rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: python -m compile.aot --out ../artifacts
Writes one `<name>.hlo.txt` per model plus `manifest.json` describing
input/output shapes for the Rust loader.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_entry(s) -> dict:
    return {"shape": list(s.shape), "dtype": s.dtype.name}


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, spec in model.CANONICAL.items():
        lowered = jax.jit(spec["fn"]).lower(*spec["args"])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        # Output shapes via abstract eval (stable across jax versions).
        out_shapes = [
            shape_entry(s) for s in jax.eval_shape(spec["fn"], *spec["args"])
        ]
        manifest[name] = {
            "file": fname,
            "inputs": [shape_entry(s) for s in spec["args"]],
            "outputs": out_shapes,
        }
        print(f"  {name}: {len(text)} chars, {len(manifest[name]['inputs'])} in, "
              f"{len(out_shapes)} out")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    print(f"AOT-lowering {len(model.CANONICAL)} models to {args.out}")
    lower_all(args.out)
    print("done")


if __name__ == "__main__":
    main()
