"""Pure-jnp oracles for the three Pallas kernels.

These define the numerical schemes of the benchmark analogs; the Pallas
kernels in this package must agree with them to float tolerance (pytest +
hypothesis enforce this). The Rust compute backends mirror the same schemes,
so L1 (Pallas), L2 (JAX model) and L3 (Rust fallback math) are mutually
consistent.
"""

import jax.numpy as jnp


def jacobi_step_ref(u_halo, f, omega, h2):
    """Weighted-Jacobi relaxation of the 7-point Poisson stencil.

    The hot loop of the AMG2023 analog's smoother and residual path.

    Args:
      u_halo: (nx+2, ny+2, nz+2) current iterate including one halo layer
        (filled by the L3 halo exchange — the paper's MatVecComm region).
      f: (nx, ny, nz) right-hand side.
      omega: relaxation weight.
      h2: grid spacing squared.

    Returns:
      (nx, ny, nz) updated interior.
    """
    c = u_halo[1:-1, 1:-1, 1:-1]
    nbr = (
        u_halo[:-2, 1:-1, 1:-1]
        + u_halo[2:, 1:-1, 1:-1]
        + u_halo[1:-1, :-2, 1:-1]
        + u_halo[1:-1, 2:, 1:-1]
        + u_halo[1:-1, 1:-1, :-2]
        + u_halo[1:-1, 1:-1, 2:]
    )
    jac = (nbr + h2 * f) / 6.0
    return (1.0 - omega) * c + omega * jac


def residual_ref(u_halo, f, h2):
    """Residual r = f - A u of the 7-point operator (A = -Δ_h)."""
    c = u_halo[1:-1, 1:-1, 1:-1]
    nbr = (
        u_halo[:-2, 1:-1, 1:-1]
        + u_halo[2:, 1:-1, 1:-1]
        + u_halo[1:-1, :-2, 1:-1]
        + u_halo[1:-1, 2:, 1:-1]
        + u_halo[1:-1, 1:-1, :-2]
        + u_halo[1:-1, 1:-1, 2:]
    )
    au = (6.0 * c - nbr) / h2
    return f - au


def sweep_plane_ref(psi_in_x, psi_in_y, psi_in_z, sigt_plane, q, dx, dy, dz):
    """Diamond-difference cell solve for one x-plane of the Kripke analog.

    Plane-lagged upwind closure (DESIGN.md §Hardware-Adaptation): the
    in-plane upwind fluxes (y, z) are taken from the upstream plane's
    outgoing fluxes, turning the KBA hyperplane recurrence into a dense
    plane-parallel update suited to a VMEM-resident Pallas block.

    Args:
      psi_in_x/y/z: (ny, nz, G, D) incoming angular flux through the
        upstream x/y/z faces.
      sigt_plane: (ny, nz) total cross-section in this plane.
      q: scalar isotropic source.
      dx, dy, dz: cell widths.

    Returns:
      (psi_out_x, psi_out_y, psi_out_z, phi_plane):
        outgoing face fluxes, each (ny, nz, G, D), and the plane's scalar
        flux (ny, nz, G) = mean over directions.
    """
    two_dx, two_dy, two_dz = 2.0 / dx, 2.0 / dy, 2.0 / dz
    sig = sigt_plane[:, :, None, None]
    num = q + two_dx * psi_in_x + two_dy * psi_in_y + two_dz * psi_in_z
    den = sig + two_dx + two_dy + two_dz
    psi = num / den
    psi_out_x = 2.0 * psi - psi_in_x
    psi_out_y = 2.0 * psi - psi_in_y
    psi_out_z = 2.0 * psi - psi_in_z
    phi = jnp.mean(psi, axis=-1)
    return psi_out_x, psi_out_y, psi_out_z, phi


def corner_forces_ref(bmat, stress):
    """Batched corner-force contraction of the Laghos analog.

    F[e] = B[e]^T @ stress[e]: per-element gradient-matrix transpose applied
    to the quadrature-weighted stress, the FLOP-dominant step of Laghos'
    force evaluation (its `ForceMult`).

    Args:
      bmat: (E, Q, N) per-element B matrices (Q quadrature points, N dofs).
      stress: (E, Q, DIM) weighted stress at quadrature points.

    Returns:
      (E, N, DIM) corner forces.
    """
    return jnp.einsum("eqn,eqd->end", bmat, stress)


def max_wavespeed_ref(stress):
    """Max characteristic speed estimate used for the dt reduction."""
    return jnp.max(jnp.abs(stress))
