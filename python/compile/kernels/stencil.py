"""L1 Pallas kernel: weighted-Jacobi 7-point stencil plane update (AMG).

TPU adaptation (DESIGN.md §Hardware-Adaptation): hypre's smoother loop is
re-tiled plane-at-a-time — the pallas_call grid walks the x dimension and
each program instance updates one (ny, nz) interior plane from the three
x-planes it depends on. The per-rank AMG tiles are small (16^3..32^3), so
the whole tile is VMEM-resident (34·34·18·4B ≈ 83 KiB ≪ 16 MiB VMEM) and
the plane windows are cut with `pl.dynamic_slice` inside the kernel; on a
real TPU the same structure maps to a double-buffered HBM→VMEM plane
pipeline via a windowed BlockSpec.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _plane_neighborhood(u3):
    """Split a (3, ny+2, nz+2) window into center and 6-neighbor sum."""
    lo = u3[0, 1:-1, 1:-1]
    c = u3[1, 1:-1, 1:-1]
    hi = u3[2, 1:-1, 1:-1]
    north = u3[1, :-2, 1:-1]
    south = u3[1, 2:, 1:-1]
    west = u3[1, 1:-1, :-2]
    east = u3[1, 1:-1, 2:]
    return c, lo + hi + north + south + west + east


def _jacobi_plane_kernel(u_ref, f_ref, o_ref, *, omega, h2):
    i = pl.program_id(0)
    u3 = pl.load(u_ref, (pl.ds(i, 3), slice(None), slice(None)))
    fpl = pl.load(f_ref, (pl.ds(i, 1), slice(None), slice(None)))[0]
    c, nbr = _plane_neighborhood(u3)
    jac = (nbr + h2 * fpl) / 6.0
    out = (1.0 - omega) * c + omega * jac
    pl.store(o_ref, (pl.ds(i, 1), slice(None), slice(None)), out[None])


def _residual_plane_kernel(u_ref, f_ref, o_ref, *, h2):
    i = pl.program_id(0)
    u3 = pl.load(u_ref, (pl.ds(i, 3), slice(None), slice(None)))
    fpl = pl.load(f_ref, (pl.ds(i, 1), slice(None), slice(None)))[0]
    c, nbr = _plane_neighborhood(u3)
    out = fpl - (6.0 * c - nbr) / h2
    pl.store(o_ref, (pl.ds(i, 1), slice(None), slice(None)), out[None])


def _plane_call(kernel, u_halo, f):
    nx, ny, nz = f.shape
    whole_u = pl.BlockSpec(u_halo.shape, lambda i: (0, 0, 0))
    whole_f = pl.BlockSpec(f.shape, lambda i: (0, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(nx,),
        in_specs=[whole_u, whole_f],
        out_specs=pl.BlockSpec((nx, ny, nz), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, ny, nz), u_halo.dtype),
        interpret=True,
    )(u_halo, f)


def jacobi_step(u_halo, f, omega=0.8, h2=1.0):
    """Pallas-backed weighted-Jacobi step; contract of `ref.jacobi_step_ref`.

    u_halo: (nx+2, ny+2, nz+2); f: (nx, ny, nz) → (nx, ny, nz).
    """
    return _plane_call(
        functools.partial(_jacobi_plane_kernel, omega=omega, h2=h2), u_halo, f
    )


def residual(u_halo, f, h2=1.0):
    """Pallas-backed residual r = f - A u; contract of `ref.residual_ref`."""
    return _plane_call(functools.partial(_residual_plane_kernel, h2=h2), u_halo, f)


def vmem_footprint_bytes(nx, ny, nz, dtype_bytes=4):
    """Estimated VMEM bytes per program instance (DESIGN.md §Perf):
    full tile + RHS + output resident."""
    u = (nx + 2) * (ny + 2) * (nz + 2) * dtype_bytes
    f = nx * ny * nz * dtype_bytes
    return u + 2 * f
