"""L1 Pallas kernel: diamond-difference plane solve (Kripke analog).

The Kripke sweep's hot spot solves every cell of a wavefront for all
(group, direction) pairs. GPU Kripke tiles this over threadblocks; the TPU
adaptation (DESIGN.md §Hardware-Adaptation) processes one full (ny, nz)
plane per program instance with the (G, D) lanes vectorized — the natural
VPU/MXU-friendly layout — using the plane-lagged upwind closure defined by
`ref.sweep_plane_ref`. The x recurrence lives one level up in the L2 model
(`model.kripke_sweep_local`, a lax.scan), mirroring how the real code walks
hyperplanes.

VMEM per instance: 4 face-flux blocks + σ_t plane + output, i.e.
~5·ny·nz·G·D·4B. For the canonical (8, 8, 8, 8) configuration that is
~655 KiB — VMEM-resident with room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sweep_plane_kernel(inx_ref, iny_ref, inz_ref, sig_ref, outx_ref, outy_ref, outz_ref, phi_ref, *, q, dx, dy, dz):
    two_dx, two_dy, two_dz = 2.0 / dx, 2.0 / dy, 2.0 / dz
    psi_in_x = inx_ref[...]
    psi_in_y = iny_ref[...]
    psi_in_z = inz_ref[...]
    sig = sig_ref[...][:, :, None, None]
    num = q + two_dx * psi_in_x + two_dy * psi_in_y + two_dz * psi_in_z
    den = sig + two_dx + two_dy + two_dz
    psi = num / den
    outx_ref[...] = 2.0 * psi - psi_in_x
    outy_ref[...] = 2.0 * psi - psi_in_y
    outz_ref[...] = 2.0 * psi - psi_in_z
    phi_ref[...] = jnp.mean(psi, axis=-1)


def sweep_plane(psi_in_x, psi_in_y, psi_in_z, sigt_plane, q=1.0, dx=1.0, dy=1.0, dz=1.0):
    """Pallas-backed plane solve; contract of `ref.sweep_plane_ref`.

    psi_in_*: (ny, nz, G, D); sigt_plane: (ny, nz).
    Returns (psi_out_x, psi_out_y, psi_out_z, phi) with phi (ny, nz, G).
    """
    ny, nz, g, d = psi_in_x.shape
    dt = psi_in_x.dtype
    kernel = functools.partial(_sweep_plane_kernel, q=q, dx=dx, dy=dy, dz=dz)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((ny, nz, g, d), dt),
            jax.ShapeDtypeStruct((ny, nz, g, d), dt),
            jax.ShapeDtypeStruct((ny, nz, g, d), dt),
            jax.ShapeDtypeStruct((ny, nz, g), dt),
        ),
        interpret=True,
    )(psi_in_x, psi_in_y, psi_in_z, sigt_plane)


def vmem_footprint_bytes(ny, nz, g, d, dtype_bytes=4):
    """Estimated VMEM bytes per program instance (DESIGN.md §Perf)."""
    flux = ny * nz * g * d * dtype_bytes
    return 6 * flux + ny * nz * dtype_bytes + ny * nz * g * dtype_bytes
