# L1: Pallas kernels (interpret=True — CPU PJRT cannot execute Mosaic
# custom-calls; see DESIGN.md §Hardware-Adaptation).
