"""L1 Pallas kernel: batched corner-force contraction (Laghos analog).

Laghos' `ForceMult` applies per-element force matrices; the FLOP core is a
batch of small dense contractions F[e] = B[e]^T · S[e]. The TPU adaptation
shapes this for the MXU: the pallas_call grid walks element blocks and each
program instance contracts a (BE, Q, N) × (BE, Q, DIM) block as a batched
matmul with `jax.lax.dot_general` over the Q (quadrature) dimension —
exactly the systolic-array-friendly contraction layout.

VMEM per instance (block of BE elements): BE·Q·(N+DIM)·4B + BE·N·DIM·4B.
For BE=16, Q=N=16, DIM=2: ~20 KiB.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _force_block_kernel(b_ref, s_ref, o_ref):
    b = b_ref[...]  # (BE, Q, N)
    s = s_ref[...]  # (BE, Q, DIM)
    # F[e,n,d] = sum_q B[e,q,n] * S[e,q,d] — batch dim e, contract q.
    o_ref[...] = jax.lax.dot_general(
        b,
        s,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def corner_forces(bmat, stress, block_e=16):
    """Pallas-backed corner forces; contract of `ref.corner_forces_ref`.

    bmat: (E, Q, N); stress: (E, Q, DIM) → (E, N, DIM).
    E must be divisible by block_e (callers use the canonical shapes).
    """
    e, q, n = bmat.shape
    _, _, dim = stress.shape
    if e % block_e != 0:
        block_e = e  # single block fallback for odd test sizes
    grid = (e // block_e,)
    return pl.pallas_call(
        _force_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_e, q, dim), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_e, n, dim), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, n, dim), bmat.dtype),
        interpret=True,
    )(bmat, stress)


def vmem_footprint_bytes(block_e, q, n, dim, dtype_bytes=4):
    """Estimated VMEM bytes per program instance (DESIGN.md §Perf)."""
    return block_e * (q * n + q * dim + n * dim) * dtype_bytes
