# Build-time compile path (L1 Pallas kernels + L2 JAX models + AOT lowering).
# Python runs ONCE at `make artifacts`; it is never on the Rust request path.
