"""L2: JAX compute graphs of the three benchmark analogs.

Each function composes the L1 Pallas kernels into the per-rank compute an
application performs between communication phases. `aot.py` lowers these
once to HLO text; the Rust coordinator executes them through PJRT on its
hot path (runtime::executor), so Python never runs at simulation time.

Canonical AOT shapes (kept moderate so interpret-mode Pallas stays fast;
the Rust fallback backend handles arbitrary sizes with identical schemes):

  amg_jacobi      u_halo (18,18,18) f32, f (16,16,16) f32
  amg_residual    same
  kripke_sweep    local zones (8,8,8), G=8, D=8
  laghos_forces   E=64 elements, Q=16, N=16, DIM=2
"""

import jax
import jax.numpy as jnp

from .kernels import hydro, stencil, sweep

# ---------------------------------------------------------------------------
# AMG2023 analog: smoother + residual (called per level between MatVecComm
# halo exchanges).
# ---------------------------------------------------------------------------


def amg_jacobi(u_halo, f):
    """One weighted-Jacobi sweep (ω = 0.8, unit h). Returns the updated
    interior; the L3 side re-inserts it and refreshes halos."""
    return (stencil.jacobi_step(u_halo, f, omega=0.8, h2=1.0),)


def amg_residual(u_halo, f):
    """Residual f - A u plus its squared norm (one fused artifact so the L3
    CG/V-cycle driver gets both without a second execution)."""
    r = stencil.residual(u_halo, f, h2=1.0)
    return r, jnp.sum(r * r)


# ---------------------------------------------------------------------------
# Kripke analog: sweep the local cube for one (octant, groupset, dirset)
# pipeline step. lax.scan walks x-planes; each step applies the L1 plane
# kernel with plane-lagged y/z upwind closure.
# ---------------------------------------------------------------------------


def kripke_sweep_local(psi_bc_x, psi_bc_y, psi_bc_z, sigt):
    """Sweep the local subdomain.

    Args:
      psi_bc_x: (ny, nz, G, D) incoming x-face flux (from the upstream rank).
      psi_bc_y: (ny, nz, G, D) incoming y-face flux, plane-lagged layout.
      psi_bc_z: (ny, nz, G, D) incoming z-face flux, plane-lagged layout.
      sigt: (nx, ny, nz) total cross-section.

    Returns:
      (psi_out_x, psi_out_y, psi_out_z, phi):
        outgoing face fluxes (ny, nz, G, D) for the three downstream ranks
        and the local scalar flux (nx, ny, nz, G).
    """

    def step(carry, sig_plane):
        px, py, pz = carry
        ox, oy, oz, phi = sweep.sweep_plane(px, py, pz, sig_plane)
        return (ox, oy, oz), phi

    (ox, oy, oz), phis = jax.lax.scan(step, (psi_bc_x, psi_bc_y, psi_bc_z), sigt)
    return ox, oy, oz, phis


# ---------------------------------------------------------------------------
# Laghos analog: corner forces + wave-speed estimate for the dt reduction.
# ---------------------------------------------------------------------------


def laghos_forces(bmat, stress):
    """Per-element corner forces and the local max wave speed (the value the
    timestep loop all-reduces — the paper's Reduction phase in Fig 4)."""
    forces = hydro.corner_forces(bmat, stress)
    wavespeed = jnp.max(jnp.abs(stress))
    return forces, wavespeed


# ---------------------------------------------------------------------------
# Canonical example inputs for AOT lowering.
# ---------------------------------------------------------------------------

CANONICAL = {
    "amg_jacobi": dict(
        fn=amg_jacobi,
        args=(
            jax.ShapeDtypeStruct((18, 18, 18), jnp.float32),
            jax.ShapeDtypeStruct((16, 16, 16), jnp.float32),
        ),
    ),
    "amg_residual": dict(
        fn=amg_residual,
        args=(
            jax.ShapeDtypeStruct((18, 18, 18), jnp.float32),
            jax.ShapeDtypeStruct((16, 16, 16), jnp.float32),
        ),
    ),
    "kripke_sweep": dict(
        fn=kripke_sweep_local,
        args=(
            jax.ShapeDtypeStruct((8, 8, 8, 8), jnp.float32),
            jax.ShapeDtypeStruct((8, 8, 8, 8), jnp.float32),
            jax.ShapeDtypeStruct((8, 8, 8, 8), jnp.float32),
            jax.ShapeDtypeStruct((8, 8, 8), jnp.float32),
        ),
    ),
    "laghos_forces": dict(
        fn=laghos_forces,
        args=(
            jax.ShapeDtypeStruct((64, 16, 16), jnp.float32),
            jax.ShapeDtypeStruct((64, 16, 2), jnp.float32),
        ),
    ),
}
