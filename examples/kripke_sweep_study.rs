//! Kripke sweep-region study (the paper's §IV-A): run the weak-scaling
//! series on both machine models at reduced size and show how `solve`
//! and `sweep_comm` times evolve — the content of Fig 1.
//!
//! ```bash
//! cargo run --release --example kripke_sweep_study [-- --full]
//! ```

use commscope::benchpark::experiment::{ExperimentSpec, Scaling};
use commscope::benchpark::runner::{run_cell, RunOptions};
use commscope::benchpark::{AppKind, SystemId};
use commscope::thicket::{stats, Thicket};
use commscope::util::cli::Args;
use commscope::util::table::{Align, TextTable};

fn main() {
    let args = Args::from_env();
    let opts = if args.has("full") {
        RunOptions::default()
    } else {
        RunOptions::smoke()
    };

    let mut runs = Vec::new();
    for system in [SystemId::Dane, SystemId::Tioga] {
        let scales = if system == SystemId::Dane {
            [64, 128, 256, 512]
        } else {
            [8, 16, 32, 64]
        };
        for nranks in scales {
            let spec = ExperimentSpec {
                app: AppKind::Kripke,
                system,
                scaling: Scaling::Weak,
                nranks,
            };
            eprintln!("running {} …", spec.id());
            runs.push(run_cell(&spec, &opts).expect("cell"));
        }
    }
    let thicket = Thicket::new(runs);

    for system in ["dane", "tioga"] {
        let group = thicket.filter(&[("system", system)]);
        let mut t = TextTable::new(&[
            "ranks",
            "main (s)",
            "solve (s)",
            "sweep_comm (s)",
            "comm/main %",
        ])
        .title(&format!(
            "Kripke weak scaling on {} — avg time per rank (Fig 1)",
            system
        ))
        .align(0, Align::Right);
        for run in group.by_ranks() {
            let main = stats::region_time_avg(run, "main").unwrap_or(0.0);
            let solve = stats::region_time_avg(run, "solve").unwrap_or(0.0);
            let comm = stats::region_time_avg(run, "sweep_comm").unwrap_or(0.0);
            t.row(vec![
                run.meta["ranks"].clone(),
                format!("{:.4}", main),
                format!("{:.4}", solve),
                format!("{:.4}", comm),
                format!("{:.1}", 100.0 * comm / main.max(1e-12)),
            ]);
        }
        println!("{}", t.render());
    }
    println!(
        "Expected shapes (paper §IV-A): solve dominates; the sweep_comm share\n\
         of main is higher on Dane (CPU) than on Tioga (GPU)."
    );
}
