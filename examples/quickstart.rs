//! Quickstart: instrument a tiny MPI-style program with communication
//! regions (RAII guards + metric channels) and print the two Caliper
//! reports.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::collections::BTreeMap;

use commscope::caliper::aggregate::aggregate;
use commscope::caliper::report::{comm_report, runtime_report};
use commscope::caliper::Caliper;
use commscope::mpisim::cart::CartComm;
use commscope::mpisim::collectives::ReduceOp;
use commscope::mpisim::{MachineModel, World, WorldConfig};

fn main() {
    // An 8-rank job on a generic test machine: a 2×2×2 cartesian grid
    // doing a few halo exchanges around a fake stencil update.
    let cfg = WorldConfig::new(8, MachineModel::test_machine());
    let profiles = World::run(cfg, |rank| {
        // Select metric channels with a Caliper-style spec string: the
        // default Table I stats plus the rank×rank comm matrix and the
        // message-size histogram.
        let cali = Caliper::attach_with(rank, "comm-stats,comm-matrix,msg-hist").unwrap();
        let cart = CartComm::new(rank.world(), &[2, 2, 2], &[false; 3]).unwrap();

        let main = cali.region("main");
        for step in 0..5 {
            // --- the paper's new marker: a communication region ---------
            {
                let _halo = cali.comm_region("halo_exchange");
                let payload = vec![step as f64; 1024];
                // Nonblocking halo: post receives, post sends, waitall.
                // Above the machine's eager threshold the sends follow the
                // rendezvous protocol, so the waitall's wait time is what
                // the mpi-time channel attributes to this region.
                let mut reqs: Vec<commscope::mpisim::Request> = Vec::new();
                for dim in 0..3 {
                    for dir in [-1i64, 1] {
                        if let Some(nbr) = cart.shift(dim, dir) {
                            reqs.push(
                                rank.irecv(Some(nbr), dim as i32, &cart.comm).unwrap().into(),
                            );
                        }
                    }
                }
                for dim in 0..3 {
                    for dir in [-1i64, 1] {
                        if let Some(nbr) = cart.shift(dim, dir) {
                            reqs.push(
                                rank.isend(&payload, nbr, dim as i32, &cart.comm).unwrap().into(),
                            );
                        }
                    }
                }
                let _ = rank.waitall::<f64>(reqs).unwrap();
            } // halo_exchange closes when the guard drops

            // --- compute phase (virtual time from the machine model) ----
            cali.scoped(rank, "stencil", |r| r.compute(2.0e7, 1.0e6));

            // --- a residual-style reduction ------------------------------
            let norm = {
                let _red = cali.comm_region("reduction");
                rank.allreduce_f64(&[step as f64], ReduceOp::Sum, &cart.comm)
                    .unwrap()
            };
            assert_eq!(norm[0], step as f64 * 8.0);
        }
        drop(main);
        cali.finish(rank)
    });

    let mut meta = BTreeMap::new();
    meta.insert("app".to_string(), "quickstart".to_string());
    meta.insert("ranks".to_string(), "8".to_string());
    let run = aggregate(meta, &profiles);

    println!("{}", runtime_report(&run));
    println!("{}", comm_report(&run));
    println!("quickstart OK: every rank exchanged 3 faces × 5 steps");
}
