//! AMG multigrid-level communication analysis (the paper's §IV-B):
//! per-level bytes (Fig 2) and source-rank fan-in (Fig 3) on both systems.
//!
//! ```bash
//! cargo run --release --example amg_levels [-- --full]
//! ```

use commscope::benchpark::experiment::{ExperimentSpec, Scaling};
use commscope::benchpark::runner::{run_cell, RunOptions};
use commscope::benchpark::{AppKind, SystemId};
use commscope::thicket::{stats, Thicket};
use commscope::util::cli::Args;
use commscope::util::table::{sci, Align, TextTable};

fn main() {
    let args = Args::from_env();
    let opts = if args.has("full") {
        RunOptions::default()
    } else {
        RunOptions::smoke()
    };

    let mut runs = Vec::new();
    for (system, scales) in [
        (SystemId::Dane, vec![64, 256, 512]),
        (SystemId::Tioga, vec![8, 32, 64]),
    ] {
        for nranks in scales {
            let spec = ExperimentSpec {
                app: AppKind::Amg2023,
                system,
                scaling: Scaling::Weak,
                nranks,
            };
            eprintln!("running {} …", spec.id());
            runs.push(run_cell(&spec, &opts).expect("cell"));
        }
    }
    let thicket = Thicket::new(runs);

    for system in ["dane", "tioga"] {
        let group = thicket.filter(&[("system", system)]);
        let mut t = TextTable::new(&["ranks", "level", "max bytes/proc", "avg src ranks"])
            .title(&format!(
                "AMG2023 per-level communication on {} (Figs 2–3)",
                system
            ))
            .align(0, Align::Right);
        for run in group.by_ranks() {
            let bytes = stats::amg_per_level(run, |r| r.bytes_sent.max());
            let srcs = stats::amg_per_level(run, |r| r.src_ranks.avg());
            for ((level, b), (_, s)) in bytes.iter().zip(&srcs) {
                t.row(vec![
                    run.meta["ranks"].clone(),
                    level.to_string(),
                    sci(*b),
                    format!("{:.1}", s),
                ]);
            }
        }
        println!("{}", t.render());
    }
    println!(
        "Expected shapes (paper §IV-B): fine levels carry the most bytes;\n\
         on dane the coarse-level source-rank fan-in explodes (>100 at 512\n\
         ranks, level ≥6) while tioga's stays bounded by balanced coarsening."
    );
}
