//! End-to-end driver: proves all layers compose on a real (small) workload.
//!
//! 1. starts the PJRT compute service over the AOT artifacts
//!    (`make artifacts` first) — L1 Pallas kernels inside L2 JAX models,
//!    executed from the Rust hot path;
//! 2. runs all three benchmark analogs with the **PJRT backend** at the
//!    canonical tile sizes, on simulated 8-rank jobs, logging solver
//!    progress (AMG residual curve, Kripke flux norms, Laghos dt curve);
//! 3. re-runs with the native backend and asserts the numerics agree
//!    (<1e-3 relative) — L1/L2/L3 consistency;
//! 4. runs a reduced experiment campaign and renders every paper table
//!    and figure into `results/e2e/`.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_campaign
//! ```

use commscope::apps::amg::{run_amg, AmgConfig, CoarseStrategy};
use commscope::apps::common::ComputeBackend;
use commscope::apps::kripke::{run_kripke, KripkeConfig};
use commscope::apps::laghos::{run_laghos, LaghosConfig};
use commscope::benchpark::runner::RunOptions;
use commscope::benchpark::system::SystemId;
use commscope::coordinator::campaign::{run_campaign, CampaignOptions};
use commscope::coordinator::figures;
use commscope::mpisim::WorldConfig;
use commscope::runtime::ComputeService;

fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-12)
}

fn main() {
    let t_start = std::time::Instant::now();

    // ---- 1. PJRT service over the artifacts ------------------------------
    let svc = match ComputeService::start("artifacts") {
        Ok(s) => s,
        Err(e) => {
            eprintln!("e2e: artifacts unavailable ({e:#}); run `make artifacts` first");
            std::process::exit(2);
        }
    };
    let handle = svc.handle();
    println!(
        "[1/4] PJRT service up on platform '{}'",
        handle.platform().unwrap_or_default()
    );

    let machine = SystemId::Tioga.machine();

    // ---- 2. all three apps through the PJRT backend ----------------------
    // AMG: canonical 16³ tile per rank, 2×2×2 ranks.
    let amg_cfg = |backend: ComputeBackend| AmgConfig {
        pdims: [2, 2, 2],
        local: [16, 16, 16],
        niter: 6,
        exchanges_per_level: 3,
        strategy: CoarseStrategy::GpuBalanced,
        backend,
        seed: 42,
        channels: commscope::caliper::ChannelConfig::default(),
    };
    let amg_pjrt = run_amg(
        WorldConfig::new(8, machine.clone()),
        &amg_cfg(ComputeBackend::Pjrt(handle.clone())),
    );
    println!(
        "[2/4] AMG (pjrt): residuals {}",
        amg_pjrt
            .residuals
            .iter()
            .map(|r| format!("{:.3e}", r))
            .collect::<Vec<_>>()
            .join(" → ")
    );
    assert!(
        amg_pjrt.residuals.last().unwrap() < &amg_pjrt.residuals[0],
        "AMG residual must decrease"
    );

    // Kripke: canonical 8³ zones, 8 groups × 8 dirs.
    let kripke_cfg = |backend: ComputeBackend| KripkeConfig {
        niter: 3,
        ..KripkeConfig::canonical_pjrt([2, 2, 2], backend)
    };
    let kripke_pjrt = run_kripke(
        WorldConfig::new(8, machine.clone()),
        &kripke_cfg(ComputeBackend::Pjrt(handle.clone())),
    );
    println!(
        "      Kripke (pjrt): ϕ-norms {}",
        kripke_pjrt
            .phi_norms
            .iter()
            .map(|r| format!("{:.5e}", r))
            .collect::<Vec<_>>()
            .join(" → ")
    );

    // Laghos: canonical 64-element patches (8×8 per rank on a 2×2 grid).
    let laghos_cfg = |backend: ComputeBackend| LaghosConfig::canonical_pjrt([2, 2], backend);
    let laghos_pjrt = run_laghos(
        WorldConfig::new(4, machine.clone()),
        &laghos_cfg(ComputeBackend::Pjrt(handle.clone())),
    );
    println!(
        "      Laghos (pjrt): dt curve {}",
        laghos_pjrt
            .dts
            .iter()
            .map(|d| format!("{:.4}", d))
            .collect::<Vec<_>>()
            .join(" → ")
    );

    // ---- 3. native backends must agree -----------------------------------
    let amg_native = run_amg(
        WorldConfig::new(8, machine.clone()),
        &amg_cfg(ComputeBackend::Native),
    );
    let kripke_native = run_kripke(
        WorldConfig::new(8, machine.clone()),
        &kripke_cfg(ComputeBackend::Native),
    );
    let laghos_native = run_laghos(
        WorldConfig::new(4, machine.clone()),
        &laghos_cfg(ComputeBackend::Native),
    );
    let mut worst: f64 = 0.0;
    for (a, b) in amg_pjrt.residuals.iter().zip(&amg_native.residuals) {
        worst = worst.max(rel_diff(*a, *b));
    }
    for (a, b) in kripke_pjrt.phi_norms.iter().zip(&kripke_native.phi_norms) {
        worst = worst.max(rel_diff(*a, *b));
    }
    for (a, b) in laghos_pjrt.dts.iter().zip(&laghos_native.dts) {
        worst = worst.max(rel_diff(*a, *b));
    }
    println!(
        "[3/4] PJRT vs native agreement: worst relative diff {:.3e} (f32 artifacts vs f64 native)",
        worst
    );
    assert!(worst < 1e-3, "backends diverged: {}", worst);

    // ---- 4. reduced campaign + all figures --------------------------------
    let mut opts = CampaignOptions::new("results/e2e");
    opts.run = RunOptions::smoke();
    opts.max_ranks = Some(128);
    opts.verbose = true;
    let thicket = run_campaign(&opts, true).expect("campaign");
    let dir = std::path::Path::new("results/e2e");
    let mut report = String::new();
    report.push_str(&figures::table1());
    report.push_str(&figures::table2());
    report.push_str(&figures::table3());
    report.push_str(&figures::table4(&thicket));
    for f in [
        figures::fig1(&thicket, Some(dir)).unwrap(),
        figures::fig2(&thicket, Some(dir)).unwrap(),
        figures::fig3(&thicket, Some(dir)).unwrap(),
        figures::fig4(&thicket, Some(dir)).unwrap(),
        figures::fig5(&thicket, Some(dir)).unwrap(),
        figures::fig6(&thicket, Some(dir)).unwrap(),
    ] {
        report.push_str(&f);
    }
    std::fs::write(dir.join("report.txt"), &report).unwrap();
    println!(
        "[4/4] campaign: {} profiles, report at results/e2e/report.txt",
        thicket.len()
    );
    println!(
        "e2e OK — full stack (Pallas→JAX→HLO→PJRT→Rust coordinator→Caliper→\n\
         Benchpark→Thicket) composed in {:.1}s",
        t_start.elapsed().as_secs_f64()
    );
}
