//! Laghos strong-scaling study (the paper's §IV-C / Fig 4 and the Laghos
//! rows of Table IV): fixed mesh, growing rank counts.
//!
//! ```bash
//! cargo run --release --example laghos_strong [-- --full]
//! ```

use commscope::benchpark::experiment::{ExperimentSpec, Scaling};
use commscope::benchpark::runner::{run_cell, RunOptions};
use commscope::benchpark::{AppKind, SystemId};
use commscope::thicket::{stats, Thicket};
use commscope::util::cli::Args;
use commscope::util::table::{sci, Align, TextTable};

fn main() {
    let args = Args::from_env();
    let (opts, scales): (RunOptions, Vec<usize>) = if args.has("full") {
        (RunOptions::default(), vec![112, 224, 448, 896])
    } else {
        (RunOptions::smoke(), vec![112, 224, 448])
    };

    let mut runs = Vec::new();
    for nranks in scales {
        let spec = ExperimentSpec {
            app: AppKind::Laghos,
            system: SystemId::Dane,
            scaling: Scaling::Strong,
            nranks,
        };
        eprintln!("running {} …", spec.id());
        runs.push(run_cell(&spec, &opts).expect("cell"));
    }
    let thicket = Thicket::new(runs);

    let mut t = TextTable::new(&[
        "ranks",
        "total bytes",
        "total sends",
        "largest send",
        "avg send",
        "timestep (s)",
        "halo (s)",
        "msg rate /proc",
    ])
    .title("Laghos strong scaling on dane (Table IV rows + Fig 4/5 content)")
    .align(0, Align::Right);
    for run in thicket.by_ranks() {
        let (bytes, sends, largest, avg) = stats::table4_row(run);
        t.row(vec![
            run.meta["ranks"].clone(),
            sci(bytes),
            sci(sends),
            largest.to_string(),
            sci(avg),
            format!("{:.4}", stats::region_time_avg(run, "timestep").unwrap_or(0.0)),
            format!(
                "{:.4}",
                stats::region_time_avg(run, "halo_exchange").unwrap_or(0.0)
            ),
            format!("{:.0}", stats::message_rate_per_proc(run).unwrap_or(0.0)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Expected shapes (paper §IV-C): per-rank times fall with scale; the\n\
         largest send shrinks (~1/sqrt(p), 2D surfaces); total sends grow\n\
         ~linearly; the per-process message rate rises toward a plateau."
    );
}
